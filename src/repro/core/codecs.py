"""Stage 3 of the pipeline: the Codec ``C`` (lossless entropy coding).

The paper uses nvCOMP on GPU; the TPU-native adaptation (DESIGN.md §3) keeps
bit-packing on device (Pallas kernel) and runs the entropy stage on the host
along the network path with **zstd** — itself an FSE/ANS entropy coder, the
closest faithful stand-in for nvCOMP's ANS.  ``bitshuffle`` transposes bit
planes first (CacheGen-style plane coding) which materially improves the
entropy stage on smooth quantized data.

``zstandard`` is an *optional* dependency (the ``zstd`` packaging extra).
Without it, the entropy stage falls back to the stdlib ``zlib`` (DEFLATE),
mapping each zstd level to a comparable zlib level.  The fallback is still
exactly lossless and byte accounting stays exact: wire bytes are always
``len()`` of whatever the active backend produced.  Encode and decode must
run with the same backend (payloads never persist across environments).

Everything here is exactly lossless (property-tested).
"""
from __future__ import annotations

import zlib
from typing import Tuple

import numpy as np

try:  # optional: the `zstd` packaging extra
    import zstandard as zstd
    HAVE_ZSTD = True
except ImportError:  # pragma: no cover - exercised by the no-zstd CI leg
    zstd = None
    HAVE_ZSTD = False

Array = np.ndarray


def backend() -> str:
    """Active entropy-coding backend: ``"zstd"`` or ``"zlib"``."""
    return "zstd" if HAVE_ZSTD else "zlib"


# ---------------------------------------------------------------------------
# Bit packing: uint8 codes with b significant bits -> dense bitstream.
# ---------------------------------------------------------------------------
def bitpack(codes: Array, bits: int) -> bytes:
    """Pack flat uint8 ``codes`` (< 2**bits) into a dense big-endian stream."""
    assert 1 <= bits <= 8
    flat = np.ascontiguousarray(codes, dtype=np.uint8).ravel()
    if bits == 8:
        return flat.tobytes()
    # (n, 8) bit matrix -> keep low ``bits`` columns -> repack.
    bitsmat = np.unpackbits(flat[:, None], axis=1)[:, 8 - bits :]
    return np.packbits(bitsmat.ravel()).tobytes()


def bitunpack(buf: bytes, bits: int, count: int) -> Array:
    """Inverse of :func:`bitpack`; returns uint8 array of length ``count``."""
    assert 1 <= bits <= 8
    if bits == 8:
        return np.frombuffer(buf, dtype=np.uint8, count=count).copy()
    raw = np.unpackbits(np.frombuffer(buf, dtype=np.uint8))
    raw = raw[: count * bits].reshape(count, bits)
    weights = (1 << np.arange(bits - 1, -1, -1)).astype(np.uint8)
    return (raw * weights).sum(axis=1).astype(np.uint8)


# ---------------------------------------------------------------------------
# Bit-plane shuffle (improves zstd on quantized data).
# ---------------------------------------------------------------------------
def bitshuffle(codes: Array, bits: int) -> bytes:
    flat = np.ascontiguousarray(codes, dtype=np.uint8).ravel()
    planes = np.unpackbits(flat[:, None], axis=1)[:, 8 - bits :]  # (n, bits)
    return np.packbits(planes.T.ravel()).tobytes()


def bitunshuffle(buf: bytes, bits: int, count: int) -> Array:
    raw = np.unpackbits(np.frombuffer(buf, dtype=np.uint8))
    planes = raw[: count * bits].reshape(bits, count).T  # (n, bits)
    weights = (1 << np.arange(bits - 1, -1, -1)).astype(np.uint8)
    return (planes * weights).sum(axis=1).astype(np.uint8)


# ---------------------------------------------------------------------------
# Codec dispatch.
# ---------------------------------------------------------------------------
_LEVELS = {"zstd1": 1, "zstd3": 3, "zstd10": 10, "bitshuffle_zstd3": 3}
# zlib fallback levels chosen to mirror the zstd speed/ratio ladder.
_ZLIB_LEVELS = {"zstd1": 1, "zstd3": 6, "zstd10": 9, "bitshuffle_zstd3": 6}


def _entropy_encode(raw: bytes, codec: str) -> bytes:
    if HAVE_ZSTD:
        return zstd.ZstdCompressor(level=_LEVELS[codec]).compress(raw)
    return zlib.compress(raw, _ZLIB_LEVELS[codec])


def _entropy_decode(buf: bytes) -> bytes:
    if HAVE_ZSTD:
        return zstd.ZstdDecompressor().decompress(buf)
    return zlib.decompress(buf)


def encode_codes(codes: Array, bits: int, codec: str) -> bytes:
    """codes (uint8, any shape) -> wire bytes for one bucket payload."""
    if codec == "none":
        return bitpack(codes, bits)
    if codec == "bitshuffle_zstd3":
        packed = bitshuffle(codes, bits)
    else:
        packed = bitpack(codes, bits)
    return _entropy_encode(packed, codec)


def decode_codes(buf: bytes, bits: int, count: int, codec: str) -> Array:
    if codec == "none":
        return bitunpack(buf, bits, count)
    packed = _entropy_decode(buf)
    if codec == "bitshuffle_zstd3":
        return bitunshuffle(packed, bits, count)
    return bitunpack(packed, bits, count)


def encode_f16(x: Array, codec: str) -> bytes:
    """Passthrough (bits>=16) buckets ship as raw/zstd'd fp16."""
    raw = np.ascontiguousarray(x, dtype=np.float16).tobytes()
    if codec == "none":
        return raw
    return _entropy_encode(raw, codec)


def decode_f16(buf: bytes, count: int, codec: str) -> Array:
    raw = buf if codec == "none" else _entropy_decode(buf)
    return np.frombuffer(raw, dtype=np.float16, count=count).copy()
