"""MoE: einsum (GShard dispatch) vs sort implementations, capacity
semantics, shared experts."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import reduce_config
from repro.models.axes import Initializer, split_tree
from repro.models.layers import apply_moe, init_moe


@pytest.fixture(scope="module")
def moe_setup():
    cfg = reduce_config(get_config("deepseek-moe-16b"))
    params, _ = split_tree(init_moe(Initializer(seed=0), cfg))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 24, cfg.d_model)) * 0.5,
                    jnp.float32)
    return cfg, params, x


def test_einsum_matches_sort_without_drops(moe_setup):
    cfg, params, x = moe_setup
    hi = replace(cfg, capacity_factor=16.0)
    y_e, aux_e = apply_moe(params, replace(hi, moe_impl="einsum"), x)
    y_s, aux_s = apply_moe(params, replace(hi, moe_impl="sort"), x)
    assert float(jnp.abs(y_e - y_s).max()) < 0.02  # bf16 compute tolerance
    assert abs(float(aux_e) - float(aux_s)) < 1e-4


@pytest.mark.parametrize("impl", ["einsum", "sort"])
def test_capacity_drops_change_output(moe_setup, impl):
    """Tiny capacity must actually drop tokens (outputs differ from the
    no-drop run) but stay finite."""
    cfg, params, x = moe_setup
    y_hi, _ = apply_moe(params, replace(cfg, capacity_factor=16.0,
                                        moe_impl=impl), x)
    y_lo, _ = apply_moe(params, replace(cfg, capacity_factor=0.25,
                                        moe_impl=impl), x)
    assert bool(jnp.isfinite(y_lo).all())
    assert float(jnp.abs(y_hi - y_lo).max()) > 1e-4


def test_shared_experts_always_active(moe_setup):
    """deepseek: shared experts fire even when routing drops everything."""
    cfg, params, x = moe_setup
    assert "shared" in params
    y, _ = apply_moe(params, replace(cfg, capacity_factor=0.01), x)
    assert float(jnp.abs(y).max()) > 0  # shared path contributes


def test_grad_flows_through_einsum_dispatch(moe_setup):
    cfg, params, x = moe_setup
    def loss(p):
        y, aux = apply_moe(p, cfg, x)
        return (y.astype(jnp.float32) ** 2).mean() + 0.01 * aux
    g = jax.grad(loss)(params)
    gn = sum(float(jnp.abs(l).sum()) for l in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0
    # router must receive gradient (aux loss + combine weights)
    assert float(jnp.abs(g["router"]).sum()) > 0
