"""Kernel micro-benchmarks: host wall-time of the interpret-mode Pallas
kernels (correctness-path) plus the *modeled TPU-v5e* bytes/FLOP analysis
that feeds the roofline (derived column)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core import codecs
from repro.kernels import ops as K


def run(smoke: bool = False) -> None:
    rng = np.random.default_rng(0)
    t, d, g = (512, 128, 64) if smoke else (2048, 128, 64)
    x = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)

    for bits in (8, 4):
        us = time_call(lambda: jax.block_until_ready(
            K.quant_pack_op(x, bits=bits, group=g)))
        n_bytes = t * d * 2
        # modeled TPU: read bf16 tile + write codes+scales, HBM-bound
        out_bytes = t * d * bits // 8 + t * (d // g) * 2
        tpu_us = (n_bytes + out_bytes) / 819e9 * 1e6
        emit(f"kernel_quant_pack_int{bits}", us,
             f"host_interp modeled_tpu_us={tpu_us:.2f} "
             f"hbm_bytes={n_bytes+out_bytes}")

    us = time_call(lambda: jax.block_until_ready(K.hadamard_op(x)))
    flops = 2 * t * d * d
    tpu_us = max(flops / 197e12, (2 * t * d * 2) / 819e9) * 1e6
    emit("kernel_hadamard", us, f"modeled_tpu_us={tpu_us:.2f} flops={flops}")

    b, hkv, gq, s = (1, 2, 4, 256) if smoke else (2, 2, 4, 1024)
    q = jnp.asarray(rng.standard_normal((b, hkv, gq, d)), jnp.float32)
    k8, ks = K.quantize_ref(jnp.asarray(
        rng.standard_normal((b, hkv, s, d)), jnp.float32), 8, g)
    v8, vs = K.quantize_ref(jnp.asarray(
        rng.standard_normal((b, hkv, s, d)), jnp.float32), 8, g)
    us = time_call(lambda: jax.block_until_ready(
        K.decode_attention_op(q, k8, ks, v8, vs, bits=8, group=g)), repeats=1)
    kv_bytes_int8 = 2 * b * hkv * s * d * 1
    kv_bytes_bf16 = 2 * b * hkv * s * d * 2
    emit("kernel_decode_attn_int8", us,
         f"hbm_traffic_ratio_vs_bf16={kv_bytes_int8/kv_bytes_bf16:.2f} "
         f"modeled_tpu_us={kv_bytes_int8/819e9*1e6:.2f}")

    # paged fused dequant-attention (ISSUE 7): same math, but K/V pages
    # are gathered through a per-slot block table instead of a dense
    # (B, S) layout — the arena's decode path
    ps = 32
    pps = s // ps
    n_pages = 1 + b * pps
    bt = rng.permutation(np.arange(1, n_pages)).reshape(b, pps)
    kcp = np.zeros((n_pages, hkv, ps, d), np.int8)
    vcp = np.zeros((n_pages, hkv, ps, d), np.int8)
    ksp = np.zeros((n_pages, hkv, ps, d // g), np.float32)
    vsp = np.zeros((n_pages, hkv, ps, d // g), np.float32)
    for i in range(b):
        for p in range(pps):
            sl = slice(p * ps, (p + 1) * ps)
            kcp[bt[i, p]], vcp[bt[i, p]] = k8[i, :, sl], v8[i, :, sl]
            ksp[bt[i, p]], vsp[bt[i, p]] = ks[i, :, sl], vs[i, :, sl]
    kv_lens = jnp.full((b,), s, jnp.int32)
    us = time_call(lambda: jax.block_until_ready(
        K.paged_attention_op(q, jnp.asarray(kcp), jnp.asarray(ksp),
                             jnp.asarray(vcp), jnp.asarray(vsp),
                             jnp.asarray(bt, jnp.int32), kv_lens,
                             bits=8, group=g)), repeats=1)
    emit("kernel_paged_attn_int8", us,
         f"pages={n_pages} page_size={ps} "
         f"hbm_traffic_ratio_vs_bf16={kv_bytes_int8/kv_bytes_bf16:.2f} "
         f"modeled_tpu_us={kv_bytes_int8/819e9*1e6:.2f}")

    # host codec throughput (the real network-path codec)
    codes = rng.integers(0, 16, size=(1 << 20) if smoke else (4 << 20),
                         dtype=np.uint8)
    for codec in ("none", "zstd3", "bitshuffle_zstd3"):
        t0 = time.perf_counter()
        buf = codecs.encode_codes(codes, 4, codec)
        enc_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        codecs.decode_codes(buf, 4, len(codes), codec)
        dec_s = time.perf_counter() - t0
        emit(f"codec_{codec}", enc_s * 1e6,
             f"enc={len(codes)/enc_s/1e6:.0f}MB/s "
             f"dec={len(codes)/dec_s/1e6:.0f}MB/s "
             f"ratio={len(codes)/2/len(buf):.2f}")


if __name__ == "__main__":
    run()
