"""Gradient compression with error feedback (beyond-paper extension).

The paper's conclusion points at generalising service-aware compression to
"parameter offloading" and other networked state movement; gradient sync is
the training-side analogue.  Two pieces:

1. ``make_grad_transform`` — quantize gradients (error-feedback corrected)
   before the optimizer; emulates the wire format of a compressed gradient
   exchange and bounds the induced error (tested).
2. ``make_cross_pod_grad_sync`` — a shard_map collective that exchanges
   *quantized* gradients across the ``pod`` axis (the cross-DCN hop that is
   bandwidth-starved in multi-pod training), keeping in-pod reductions in
   full precision.  Wire bytes drop by 16/bits on the pod link.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distribution.kv_transfer import (
    dequantize_sym,
    pack_int4,
    quantize_sym,
    unpack_int4,
)


def _quant_roundtrip(g: jnp.ndarray, bits: int, group: int) -> jnp.ndarray:
    if g.ndim == 0 or g.shape[-1] % min(group, max(g.shape[-1], 1)):
        return g
    gg = min(group, g.shape[-1])
    q, scale = quantize_sym(g, bits, gg)
    return dequantize_sym(q, scale, gg, dtype=jnp.float32)


def make_grad_transform(bits: int = 8, group: int = 64,
                        error_feedback: bool = True) -> Callable:
    """grad_transform(grads, opt_state) -> (grads_hat, opt_state).

    opt_state must carry an "ef" tree (zeros_like grads) when
    error_feedback=True — see ``init_ef_state``."""

    def transform(grads, opt_state):
        if error_feedback and "ef" in opt_state:
            corrected = jax.tree_util.tree_map(
                lambda g, e: g.astype(jnp.float32) + e, grads, opt_state["ef"])
        else:
            corrected = grads
        g_hat = jax.tree_util.tree_map(
            lambda g: _quant_roundtrip(g, bits, group), corrected)
        if error_feedback and "ef" in opt_state:
            new_ef = jax.tree_util.tree_map(
                lambda c, h: c - h.astype(jnp.float32), corrected, g_hat)
            opt_state = {**opt_state, "ef": new_ef}
        return g_hat, opt_state

    return transform


def init_ef_state(grads_like) -> Dict[str, Any]:
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32)
        if not isinstance(x, jax.ShapeDtypeStruct)
        else jax.ShapeDtypeStruct(x.shape, jnp.float32),
        grads_like)


def make_cross_pod_grad_sync(mesh: Mesh, grads_example, param_specs,
                             bits: int = 8, group: int = 64):
    """Average gradients across pods with quantized exchange.

    Each pod keeps its own grads in f32 and receives its peers' grads as
    int codes + f16 scales.  For npod pods the exchange runs a ring of
    npod-1 quantized hops."""
    npod = mesh.shape["pod"]
    assert npod >= 2

    def pod_specs(spec):
        # grads are sharded like params over (data, model); the pod axis is
        # pure DP (replicated grads per pod pre-sync).
        return spec

    specs = param_specs

    def body(grads):
        def sync_leaf(g):
            if g.ndim == 0:
                acc = g
                for k in range(1, npod):
                    perm = [(i, (i + k) % npod) for i in range(npod)]
                    acc = acc + jax.lax.ppermute(g, "pod", perm)
                return acc / npod
            gg = min(group, g.shape[-1])
            packable = g.shape[-1] % gg == 0 and gg % 2 == 0
            acc = g.astype(jnp.float32)
            for k in range(1, npod):
                perm = [(i, (i + k) % npod) for i in range(npod)]
                if not packable:
                    acc = acc + jax.lax.ppermute(g, "pod", perm).astype(jnp.float32)
                    continue
                q, scale = quantize_sym(g, bits, gg)
                if bits == 4:
                    q = pack_int4(q)
                q = jax.lax.ppermute(q, "pod", perm)
                scale = jax.lax.ppermute(scale, "pod", perm)
                if bits == 4:
                    q = unpack_int4(q)
                acc = acc + dequantize_sym(q, scale, gg, dtype=jnp.float32)
            return (acc / npod).astype(g.dtype)

        return jax.tree_util.tree_map(sync_leaf, grads)

    from repro.utils.compat import shard_map_compat
    mapped = shard_map_compat(body, mesh=mesh, in_specs=(specs,),
                              out_specs=specs, check=False)
    return jax.jit(mapped)
