"""Quantizer stage: error bounds, bucketing, metadata accounting."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.quantizers import (
    group_dequantize,
    group_quantize,
    head_importance_scores,
    quantize_tensor,
)
from repro.core.strategy import StrategyConfig


@settings(max_examples=25, deadline=None)
@given(
    bits=st.integers(2, 8),
    grouping=st.sampled_from(["per_head", "per_channel", "per_token"]),
    group_size=st.sampled_from([16, 32, 64]),
    symmetric=st.booleans(),
    seed=st.integers(0, 10_000),
)
def test_group_quant_error_bound(bits, grouping, group_size, symmetric, seed):
    """|dequant - x| <= scale/2 + eps per element (asym); 2x for symmetric
    clamp of the most-negative code."""
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((6, 48, 32)) * 5).astype(np.float32)
    codes, scale, zp = group_quantize(x, bits, grouping, group_size, symmetric)
    out = group_dequantize(codes, scale, zp, bits, grouping, group_size,
                           symmetric)
    # reconstruct per-element scale bound
    qmax = (1 << bits) - 1
    if grouping == "per_head":
        rng_per = (x.max(axis=(1, 2)) - x.min(axis=(1, 2)))[:, None, None]
    else:
        rng_per = np.full_like(x, np.ptp(x))
    bound = rng_per / max(qmax, 1) * (1.0 if not symmetric else 2.0) + 1e-4
    assert (np.abs(out - x) <= bound + 1e-5).all()


def test_error_decreases_with_bits():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((4, 64, 32)).astype(np.float32)
    errs = []
    for bits in (2, 4, 8):
        c, s, z = group_quantize(x, bits, "per_channel", 32, False)
        out = group_dequantize(c, s, z, bits, "per_channel", 32, False)
        errs.append(np.abs(out - x).mean())
    assert errs[0] > errs[1] > errs[2]


def _x4(seed=0, L=4, H=4, S=96, D=32, scale=1.0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal((L, H, S, D)) * scale).astype(np.float32)


def test_uniform_buckets_single():
    x = _x4()
    qt = quantize_tensor(x, StrategyConfig(quantizer="uniform", key_bits=4),
                         is_key=True)
    assert len(qt.buckets) == 1 and qt.buckets[0].bits == 4
    assert qt.dequantize().shape == x.shape


def test_cachegen_layer_tiers():
    x = _x4(L=10)
    cfg = StrategyConfig(quantizer="cachegen", tier_bits=(8, 4, 2),
                         tier_fracs=(0.2, 0.3))
    qt = quantize_tensor(x, cfg, is_key=True)
    bits_seen = sorted(b.bits for b in qt.buckets)
    assert bits_seen == [2, 4, 8]
    # earlier layers must have MORE bits
    layer_bits = {}
    for b in qt.buckets:
        for (l, h) in b.lh_index:
            layer_bits[int(l)] = b.bits
    assert layer_bits[0] >= layer_bits[5] >= layer_bits[9]


def test_mixhq_head_allocation():
    x = _x4(H=8)
    # make heads 0,1 high-variance (retrieval-like) in every layer
    x[:, :2] *= 10
    cfg = StrategyConfig(quantizer="mixhq", mixhq_high_bits=8,
                         mixhq_low_bits=2, retrieval_frac=0.25)
    qt = quantize_tensor(x, cfg, is_key=True)
    by_bits = {b.bits: b for b in qt.buckets}
    assert set(by_bits) == {8, 2}
    high_heads = set(map(tuple, by_bits[8].lh_index.tolist()))
    assert all(h in (0, 1) for (_, h) in high_heads)
    # retrieval heads reconstruct much better than streaming heads
    out = qt.dequantize()
    err_hi = np.abs(out[:, :2] - x[:, :2]).mean() / np.abs(x[:, :2]).mean()
    err_lo = np.abs(out[:, 2:] - x[:, 2:]).mean() / np.abs(x[:, 2:]).mean()
    assert err_hi < err_lo


def test_mixhq_layer_pyramid_shaves_deep_layers():
    x = _x4(L=9, H=4)
    cfg = StrategyConfig(quantizer="mixhq", mixhq_high_bits=8,
                         mixhq_low_bits=3, retrieval_frac=0.25,
                         layer_pyramid=True)
    qt = quantize_tensor(x, cfg, is_key=True)
    assert any(b.bits == 2 for b in qt.buckets)  # 3-1 on deep streaming heads


def test_mixhq_heavy_hitter_tokens():
    x = _x4(S=64)
    cfg = StrategyConfig(quantizer="mixhq", mixhq_high_bits=8,
                         mixhq_low_bits=2, retrieval_frac=0.25,
                         token_heavy_hitter_frac=0.1)
    qt = quantize_tensor(x, cfg, is_key=True)
    assert any(b.token_index is not None for b in qt.buckets)
    assert qt.dequantize().shape == x.shape


def test_duo_prunes_streaming_heads():
    x = _x4(S=300)
    cfg = StrategyConfig(quantizer="duo", retrieval_frac=0.25, duo_sink=4,
                         duo_recent=64)
    qt = quantize_tensor(x, cfg, is_key=True)
    out = qt.dequantize()
    # middle tokens of streaming heads are zeroed (pruned)...
    stream_bucket = [b for b in qt.buckets if b.token_index is not None][0]
    l, h = stream_bucket.lh_index[0]
    assert np.abs(out[l, h, 100:200]).max() == 0.0
    # ...while kept positions match exactly (fp16)
    np.testing.assert_allclose(out[l, h, :4], x[l, h, :4], atol=2e-2,
                               rtol=1e-2)


def test_head_scores_shape():
    x = _x4(L=3, H=5)
    assert head_importance_scores(x).shape == (3, 5)


def test_payload_and_meta_accounting():
    x = _x4()
    cfg = StrategyConfig(quantizer="kivi", key_bits=2, value_bits=2,
                         group_size=32)
    qt = quantize_tensor(x, cfg, is_key=True)
    assert qt.payload_bits() == x.size * 2
    assert qt.meta_bytes() > 0
