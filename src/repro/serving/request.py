"""Request / session model for the disaggregated serving runtime."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from repro.data.synthetic import WORKLOADS


@dataclass
class Request:
    rid: int
    workload: str            # router-provided label w (Sec. 2.2)
    arrival: float           # seconds
    ctx_tokens: int          # prompt length
    out_tokens: int          # decode length
    kv_bytes: float          # uncompressed KV payload V
    t_slo: float = 0.0       # 0 = no SLO
    q_min: float = 0.97
    prefix_hit: bool = False  # pool scenario: reusable KV exists remotely

    # ---- outcome fields (filled by the simulator) ----
    done: float = 0.0
    ttft: float = 0.0
    breakdown: Dict[str, float] = field(default_factory=dict)
    chosen: str = ""
    slo_violated: bool = False
    retries: int = 0

    @property
    def jct(self) -> float:
        return self.done - self.arrival


def kv_bytes_for(ctx_tokens: int, num_layers: int, kv_heads: int,
                 head_dim: int, bytes_per_el: int = 2) -> float:
    return 2.0 * num_layers * kv_heads * head_dim * ctx_tokens * bytes_per_el


@dataclass
class WorkloadMix:
    """Poisson arrivals over a workload mix."""

    rate: float = 4.0                      # requests/s
    mix: Optional[Dict[str, float]] = None
    ctx_scale: float = 1.0
    seed: int = 0
    model_layers: int = 32
    model_kv_heads: int = 8
    model_head_dim: int = 128
    slo: float = 0.0
    q_min: float = 0.97
    prefix_hit_rate: float = 0.0

    def generate(self, n: int):
        rng = np.random.default_rng(self.seed)
        mix = self.mix or {w: 1.0 for w in WORKLOADS}
        names = list(mix)
        probs = np.asarray([mix[w] for w in names], dtype=float)
        probs /= probs.sum()
        t = 0.0
        out = []
        for i in range(n):
            t += rng.exponential(1.0 / self.rate)
            w = names[int(rng.choice(len(names), p=probs))]
            spec = WORKLOADS[w]
            ctx = int(max(64, rng.lognormal(
                np.log(spec.ctx_scale * self.ctx_scale * 16), 0.4)))
            gen = int(max(4, rng.poisson(spec.out_scale * 4)))
            out.append(Request(
                rid=i, workload=w, arrival=t, ctx_tokens=ctx, out_tokens=gen,
                kv_bytes=kv_bytes_for(ctx, self.model_layers,
                                      self.model_kv_heads, self.model_head_dim),
                t_slo=self.slo, q_min=self.q_min,
                prefix_hit=bool(rng.random() < self.prefix_hit_rate),
            ))
        return out
