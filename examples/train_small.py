"""Train a small LM for a few hundred steps with checkpoint/restart and
(optionally) error-feedback gradient compression.

    PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import argparse
import tempfile

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--grad-bits", type=int, default=0)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as d:
        _, _, losses = train(
            "tiny-lm", steps=args.steps, batch=8, seq=128, lr=3e-3,
            ckpt_dir=d, ckpt_every=50,
            grad_compress_bits=args.grad_bits, log_every=25)
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"over {len(losses)} steps")


if __name__ == "__main__":
    main()
