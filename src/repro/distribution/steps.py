"""Step builders: train_step / prefill_step / decode_step (serve_step).

These are the functions the launcher jits with explicit in/out shardings and
the dry-run lowers for every (arch × shape × mesh) cell.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distribution.optimizer import OptConfig, adamw_update
from repro.models import decode_step as model_decode
from repro.models import forward, prefill
from repro.models.io import vision_split

AUX_LOSS_WEIGHT = 0.01


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray,
                  mask: Optional[jnp.ndarray]) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def loss_fn(cfg: ModelConfig, params, batch, remat: bool = False):
    """Next-token loss. batch["tokens"] is (B, T+1) unshifted."""
    tokens = batch["tokens"]
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    fwd_batch = {**batch, "tokens": inputs}
    logits, aux = forward(cfg, params, fwd_batch, remat=remat)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        s_vis = batch["patch_embeds"].shape[1]
        logits = logits[:, s_vis:, :]
    mask = batch.get("mask")
    ce = cross_entropy(logits, targets, mask)
    return ce + AUX_LOSS_WEIGHT * aux, {"ce": ce, "aux": aux}


def make_train_step(cfg: ModelConfig, oc: OptConfig, remat: bool = True,
                    grad_transform: Optional[Callable] = None):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, remat=remat), has_aux=True
        )(params)
        if grad_transform is not None:
            grads, opt_state = grad_transform(grads, opt_state)
        params, opt_state, om = adamw_update(params, grads, opt_state, oc)
        metrics = {"loss": loss, **parts, **om}
        return params, opt_state, metrics

    return step


def make_prefill_step(cfg: ModelConfig, max_len: int):
    """(params, batch) -> (last-token logits, caches)."""

    def step(params, batch):
        return prefill(cfg, params, batch, max_len=max_len)

    return step


def make_decode_step(cfg: ModelConfig):
    """(params, caches, tokens, pos) -> (logits, caches) — serve_step."""

    def step(params, caches, tokens, pos):
        return model_decode(cfg, params, caches, tokens, pos)

    return step


def make_eval_step(cfg: ModelConfig):
    def step(params, batch):
        loss, parts = loss_fn(cfg, params, batch, remat=False)
        return {"loss": loss, **parts}

    return step
