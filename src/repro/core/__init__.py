"""KVServe core: the paper's unified KV compression pipeline + strategy space."""
from repro.core.kvcache import KVCache
from repro.core.pipeline import CompressedKV, CompressionPipeline
from repro.core.profiles import IDENTITY_PROFILE, Profile, measure_profile
from repro.core.strategy import (
    BASELINES,
    IDENTITY_STRATEGY,
    StrategyConfig,
    enumerate_space,
    estimate_cr,
    is_identity,
    space_sizes,
)

__all__ = [
    "KVCache",
    "CompressedKV",
    "CompressionPipeline",
    "Profile",
    "IDENTITY_PROFILE",
    "measure_profile",
    "StrategyConfig",
    "BASELINES",
    "IDENTITY_STRATEGY",
    "enumerate_space",
    "estimate_cr",
    "is_identity",
    "space_sizes",
]
