"""Multi-device semantics via subprocess (forced host devices): compressed
cross-pod KV transfer, compressed gradient sync, mini dry-run.

These must run in fresh processes because jax locks the device count at
first init.
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).parent.parent
SRC = str(ROOT / "src")


def _run(code: str, devices: int = 8, timeout: int = 560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-4000:]}"
    return r.stdout


def test_kv_transfer_roundtrip_and_compression():
    """ppermute KV migration: pods swap caches; int8 payload ~matches bf16."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.distribution.kv_transfer import make_kv_transfer, transfer_wire_bytes

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
rng = np.random.default_rng(0)
cache = {"layer0": {"k": jnp.asarray(rng.standard_normal((4, 32, 2, 64)), jnp.bfloat16),
                    "v": jnp.asarray(rng.standard_normal((4, 32, 2, 64)), jnp.bfloat16)}}
with mesh:
    fn16, specs = make_kv_transfer(mesh, cache, bits=16)
    fn8, _ = make_kv_transfer(mesh, cache, bits=8)
    sharded = jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), cache, specs,
        is_leaf=lambda x: hasattr(x, "shape"))
    out16 = fn16(sharded)
    out8 = fn8(sharded)

# pod axis is the leading batch factor: batch 4 over pod=2,data=2 -> batch
# sharded (pod,data). ppermute swaps pod shards: rows [0,1] <-> [2,3].
k = np.asarray(cache["layer0"]["k"], np.float32)
got16 = np.asarray(out16["layer0"]["k"], np.float32)
expected = np.concatenate([k[2:], k[:2]], axis=0)
assert np.allclose(got16, expected, atol=1e-2), "bf16 permute mismatch"
got8 = np.asarray(out8["layer0"]["k"], np.float32)
err = np.abs(got8 - expected).max()
assert err < 0.06, f"int8 transfer error too large: {err}"
w16 = transfer_wire_bytes(cache, 16); w8 = transfer_wire_bytes(cache, 8); w4 = transfer_wire_bytes(cache, 4)
assert w8 < 0.6 * w16 and w4 < 0.35 * w16, (w16, w8, w4)
print("ok", w16, w8, w4)
""")
    assert "ok" in out


def test_collective_bytes_drop_with_compression():
    """The roofline's collective term shrinks ~16/bits for the transfer."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding
from repro.launch.mesh import make_mesh
from repro.distribution.kv_transfer import make_kv_transfer
from repro.launch.hlo_cost import analyze_hlo_text

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
cache = {"k": jnp.zeros((4, 256, 2, 64), jnp.bfloat16)}
with mesh:
    res = {}
    for bits in (16, 8, 4):
        fn, specs = make_kv_transfer(mesh, cache, bits=bits)
        comp = fn.lower(jax.tree_util.tree_map(
            lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype), cache, specs)).compile()
        res[bits] = analyze_hlo_text(comp.as_text()).coll_bytes
assert res[8] < 0.62 * res[16], res
assert res[4] < 0.40 * res[16], res
print("ok", res)
""")
    assert "ok" in out


def test_cross_pod_grad_sync():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.distribution.grad_compress import make_cross_pod_grad_sync

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
rng = np.random.default_rng(1)
g = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
# different grads per pod: shard over pod on axis 0
specs = {"w": P("pod", None)}
with mesh:
    fn = make_cross_pod_grad_sync(mesh, {"w": g}, specs, bits=8)
    gs = jax.device_put(g, NamedSharding(mesh, specs["w"]))
    out = fn({"w": gs})["w"]
got = np.asarray(out)
# every pod's shard becomes the average of the two pod shards
gn = np.asarray(g)
avg = (gn[:4] + gn[4:]) / 2
assert np.abs(got[:4] - avg).max() < 0.02, np.abs(got[:4] - avg).max()
assert np.abs(got[4:] - avg).max() < 0.02
print("ok")
""")
    assert "ok" in out


@pytest.mark.slow
def test_dryrun_tiny_both_meshes():
    """The dry-run machinery end-to-end on the 512-device production meshes
    (tiny arch so it compiles in seconds)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "tiny-lm",
         "--shape", "train_4k,decode_32k", "--mesh", "both"],
        capture_output=True, text=True, timeout=560, env=env,
        cwd=str(ROOT))
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    assert r.stdout.count("[ok]") == 4
