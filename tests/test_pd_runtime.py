"""PD-disaggregated continuous runtime: two overlapped streams joined by
a serialized compressed-KV wire (DESIGN.md §9, ISSUE 3)."""
import json

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.profiles import IDENTITY_PROFILE, Profile
from repro.core.strategy import StrategyConfig
from repro.serving import BandwidthTrace, GBPS, SchedulerConfig


def _profile(cr=2.0, bits=8, codec=None):
    kw = {"codec": codec} if codec else {}
    return Profile(StrategyConfig(quantizer="uniform", key_bits=bits,
                                  value_bits=bits, granularity="per_channel",
                                  **kw),
                   cr=cr, s_enc=5e8, s_dec=5e8)


def _pd_runtime(reference_model, *, seq=64, decode_tokens=6,
                bandwidth=1 * GBPS, max_prefills=2, max_slots=6, **kw):
    from repro.serving.engine import RuntimeConfig, ServingRuntime
    defaults = dict(
        static_profile=_profile(),
        config=RuntimeConfig(seq=seq, decode_tokens=decode_tokens,
                             prefill_tok_s=2000.0, decode_tok_s=500.0,
                             mode="pd"),
        trace=BandwidthTrace.constant(bandwidth),
        scheduler=SchedulerConfig(max_slots=max_slots,
                                  max_prefills_per_step=max_prefills,
                                  max_queue=32))
    defaults.update(kw)
    rt = ServingRuntime(**defaults)
    rt.model_cfg, rt.params = reference_model
    return rt


# ---------------------------------------------------------------------------
# Token parity vs the pinned PR-1 fixture
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_pd_runtime_token_parity_with_pr1_fixture(reference_model):
    """The PD runtime must reproduce the pinned PR-1 tokens bit-for-bit
    across the pool hit/miss mix — the cold path's arena materialization
    is numerically identical to the pool path's, even though every cold
    request's compressed KV now crosses the wire on its critical path."""
    from _runtime_scenario import FIXTURE, params_digest, run_scenario
    fix = json.loads(FIXTURE.read_text())
    rt = _pd_runtime(reference_model)
    if params_digest(rt.params) != fix["params_digest"]:
        pytest.skip("reference model differs from the fixture's "
                    "(e.g. CI trains a smaller REPRO_REF_STEPS model)")
    out = run_scenario(rt)
    assert set(out) == set(fix["outputs"])
    for rid, rec in fix["outputs"].items():
        assert out[rid]["pool_hit"] == rec["pool_hit"], rid
        assert out[rid]["tokens"] == rec["tokens"], rid
    # and the PD invariant: every request moved real bytes over the wire
    assert rt.wire.transfers == len(out)
    assert rt.wire.bytes_moved > 0


# ---------------------------------------------------------------------------
# The PD critical path
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_pd_cold_request_critical_path_stages(reference_model):
    """A cold PD request pays prefill -> compress -> comm -> decompress ON
    its critical path (pool mode books only prefill there)."""
    rt = _pd_runtime(reference_model)
    rt.submit("qalike", prompt_seed=3)
    rt.run()
    (r,) = rt.completed
    assert not r.pool_hit
    for key in ("prefill", "compress", "comm", "decompress"):
        assert r.breakdown.get(key, 0.0) > 0.0, (key, r.breakdown)
    assert r.t_pool_write == 0.0   # no off-path write in PD mode
    # TTFT = first token at the decode worker, after the wire
    stages = (r.breakdown["queue"] + r.breakdown["prefill"]
              + r.breakdown["compress"] + r.breakdown.get("wire_wait", 0.0)
              + r.breakdown["comm"] + r.breakdown["decompress"])
    assert r.ttft == pytest.approx(stages, abs=1e-9)
    assert sum(r.breakdown.values()) == pytest.approx(r.jct, abs=1e-9)
    # compressed on the wire: fewer bytes than the logical KV payload
    assert 0 < r.wire_bytes < r.kv_bytes


@pytest.mark.slow
def test_pd_prefix_hit_skips_prefill_and_reuses_wire_bytes(reference_model):
    """An identical prompt later hits the decode-side pool: no prefill,
    and it fetches exactly the bytes the cold request pushed."""
    rt = _pd_runtime(reference_model)
    rt.submit("qalike", prompt_seed=9)
    rt.run()
    rt.submit("qalike", prompt_seed=9)
    rt.run()
    cold, hit = rt.completed
    assert not cold.pool_hit and hit.pool_hit
    assert hit.breakdown.get("prefill", 0.0) == 0.0
    assert hit.wire_bytes == cold.wire_bytes
    assert hit.ttft < cold.ttft
    assert len(hit.tokens) == len(cold.tokens) == rt.cfg.decode_tokens + 1


@pytest.mark.slow
def test_pd_wire_serializes_concurrent_transfers(reference_model):
    """Two cold requests admitted the same iteration contend for the wire:
    the second transfer queues behind the first (wire_wait > 0), and the
    transfers never overlap."""
    # wire slow enough that a transfer outlasts the next prefill+compress
    rt = _pd_runtime(reference_model, bandwidth=0.002 * GBPS)
    rt.submit("qalike", prompt_seed=0)
    rt.submit("codelike", prompt_seed=1)
    rt.step()             # both admitted this iteration (max_prefills=2)
    rt.run()
    by_rid = {r.rid: r for r in rt.completed}
    first, second = by_rid[0], by_rid[1]
    # the first sender never waits; the second queues behind it on the
    # wire for longer than its own head start (prefill is cheap here)
    assert first.breakdown.get("wire_wait", 0.0) == 0.0
    assert second.breakdown.get("wire_wait", 0.0) > 0.0
    for r in rt.completed:
        assert sum(r.breakdown.values()) == pytest.approx(r.jct, abs=1e-9)


@pytest.mark.slow
def test_pd_streams_overlap(reference_model):
    """Request N+1's prefill/transfer proceeds while N decodes: with both
    streams busy, the iteration costs max(streams), not their sum."""
    rt = _pd_runtime(reference_model, max_prefills=1)
    rt.submit("qalike", prompt_seed=0)
    rt.step()             # rid 0: prefill + transfer
    rt.submit("codelike", prompt_seed=1)
    log_before = len(rt.step_log)
    stats = rt.step()     # rid 1 starts WHILE rid 0 decodes
    assert len(rt.step_log) == log_before + 1
    assert stats["in_flight"] == 2.0
    step_cost = rt.step_log[-1]["clock"] - rt.step_log[-2]["clock"]
    r1_start = next(s for s in (rt._slots[1],)).breakdown
    start_work = (r1_start["prefill"] + r1_start["compress"]
                  + r1_start.get("wire_wait", 0.0) + r1_start["comm"]
                  + r1_start["decompress"])
    decode_cost = 1.0 / rt.cfg.decode_tok_s
    assert step_cost == pytest.approx(max(start_work, decode_cost), rel=1e-9)
    rt.run()


@pytest.mark.slow
def test_pd_lifecycle_states(reference_model):
    """Explicit request lifecycle: waiting -> prefilling -> transferring ->
    decoding -> done (rejected is terminal for shed load)."""
    rt = _pd_runtime(reference_model, max_prefills=1, max_slots=2,
                     scheduler=SchedulerConfig(max_slots=2,
                                               max_prefills_per_step=1,
                                               max_queue=3))
    rt.submit("qalike", prompt_seed=0)
    rt.submit("codelike", prompt_seed=1)
    rt.submit("mathlike", prompt_seed=2)
    assert rt.submit("summlike", prompt_seed=3) is None  # queue bound = 3
    shed = rt.scheduler.admission.rejected
    assert shed == 1
    counts = rt.scheduler.state_counts()
    assert counts == {"waiting": 3}
    rt.step()
    counts = rt.scheduler.state_counts()
    assert counts.get("decoding") == 1 and counts.get("waiting") == 2
    rt.run()
    assert all(req.state == "done" for req in rt.scheduler.finished)


@pytest.mark.slow
def test_pd_slo_metric_defaults_to_jct(reference_model):
    """PD scenario default SLO metric is JCT: the violation flag and the
    controller observation both use it."""
    class Spy:
        def __init__(self, profile):
            self.profile, self.observed = profile, []

        def select(self, ctx):
            from repro.controller import Decision
            return Decision(self.profile, 0, 0, 0.0)

        def observe(self, ctx, decision, latency):
            self.observed.append((ctx.slo_metric, float(latency)))

    spy = Spy(_profile())
    rt = _pd_runtime(reference_model, controller=spy, static_profile=None)
    rt.submit("qalike", prompt_seed=5, t_slo=1e-6)   # unmeetable SLO
    rt.run()
    (r,) = rt.completed
    assert r.slo_metric == "jct" and r.slo_violated
    assert len(spy.observed) == 1
    metric, obs = spy.observed[0]
    assert metric == "jct"
    assert obs == pytest.approx(r.jct, abs=1e-9)
    assert obs == pytest.approx(sum(r.breakdown.values()), abs=1e-9)


# ---------------------------------------------------------------------------
# Compression pays at low bandwidth, identity wins at high bandwidth
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_pd_compression_crossover(reference_model):
    """The paper's headline mechanism in the continuous runtime: at 50 Mbps
    a compressed profile beats identity on mean JCT; at 100 Gbps identity
    wins (codec time no longer buys anything)."""
    def mean_jct(profile, bandwidth):
        rt = _pd_runtime(reference_model, static_profile=profile,
                         bandwidth=bandwidth)
        for i, w in enumerate(("qalike", "codelike", "mathlike", "summlike")):
            rt.submit(w, prompt_seed=10 + i)
            rt.step()
        rt.run()
        assert all(not r.pool_hit for r in rt.completed)
        return float(np.mean([r.jct for r in rt.completed]))

    comp = _profile(cr=6.0, bits=4, codec="zstd3")
    low = 50e6 / 8     # 50 Mbps in bytes/s
    high = 100 * GBPS
    assert mean_jct(comp, low) < mean_jct(IDENTITY_PROFILE, low)
    assert mean_jct(IDENTITY_PROFILE, high) < mean_jct(comp, high)


# ---------------------------------------------------------------------------
# Property: breakdowns sum exactly to JCT under mixed traffic
# ---------------------------------------------------------------------------
_MODEL = None


def _cached_model():
    global _MODEL
    if _MODEL is None:
        from repro.core.quality import get_reference_model
        _MODEL = get_reference_model()
    return _MODEL


@pytest.mark.slow
@settings(max_examples=6, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    mode=st.sampled_from(["pool", "pd"]),
    max_prefills=st.sampled_from([2, 3]),
)
def test_breakdowns_sum_to_jct_property(seed, mode, max_prefills):
    """Per-request breakdowns sum exactly to JCT with
    max_prefills_per_step > 1 and mixed hit/miss/PD traffic — in BOTH
    serving scenarios, and TTFT never exceeds JCT."""
    from repro.serving.engine import RuntimeConfig, ServingRuntime

    rng = np.random.default_rng(seed)
    rt = ServingRuntime(
        static_profile=_profile(),
        config=RuntimeConfig(seq=48, decode_tokens=5, prefill_tok_s=2000.0,
                             decode_tok_s=500.0, mode=mode),
        trace=BandwidthTrace.constant(0.2 * GBPS),
        scheduler=SchedulerConfig(max_slots=4,
                                  max_prefills_per_step=max_prefills,
                                  max_queue=32))
    rt.model_cfg, rt.params = _cached_model()
    workloads = ("qalike", "codelike", "mathlike", "summlike")
    n = int(rng.integers(4, 9))
    for _ in range(n):
        rt.submit(workloads[int(rng.integers(4))],
                  prompt_seed=int(rng.integers(3)),   # repeats => pool hits
                  out_tokens=int(rng.integers(2, 6)),
                  slo_class=("interactive", "standard",
                             "batch")[int(rng.integers(3))])
        for _ in range(int(rng.integers(3))):
            rt.step()
    done = rt.run()
    assert len(done) == n
    for r in done:
        assert sum(r.breakdown.values()) == pytest.approx(r.jct, abs=1e-9), \
            (mode, r.rid, r.breakdown, r.jct)
        assert 0 < r.ttft <= r.jct + 1e-12
        assert all(v >= -1e-12 for v in r.breakdown.values()), r.breakdown
