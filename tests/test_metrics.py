"""Regression tests for latency-summary edge cases (ISSUE 6).

A grid sweep routinely produces cells where an SLO class has zero or one
completed request (everything shed, or a single straggler).  The summary
must stay total: no crash, no NaN, no RuntimeWarning — empty classes are
reported explicitly (completed 0, percentiles None, violation rate 0.0)
rather than silently dropped.
"""
import warnings
from dataclasses import dataclass, field
from typing import Dict

import numpy as np
import pytest

from repro.serving.metrics import (
    class_latency_blocks,
    latency_summary,
    percentile_row,
    violation_rates,
)
from repro.serving.simulator import SimResult


@dataclass
class _Req:
    ttft: float = 0.5
    jct: float = 1.0
    slo_class: str = "standard"
    t_slo: float = 2.0
    slo_violated: bool = False
    chosen: str = "u8"
    done: float = 1.0
    route: str = ""
    breakdown: Dict[str, float] = field(default_factory=dict)


def _no_warnings(fn, *args, **kw):
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        return fn(*args, **kw)


def test_percentile_row_empty_returns_no_keys():
    assert _no_warnings(percentile_row, [], "ttft") == {}


def test_percentile_row_single_value():
    row = _no_warnings(percentile_row, [0.7], "jct")
    assert row == {"jct_p50": 0.7, "jct_p95": 0.7, "jct_p99": 0.7}


def test_violation_rates_forces_named_classes():
    reqs = [_Req(slo_class="interactive", slo_violated=True)]
    out = _no_warnings(violation_rates, reqs,
                       classes=("interactive", "batch"))
    assert out["slo_violation_rate"] == 1.0
    assert out["slo_violation_rate_interactive"] == 1.0
    assert out["slo_violation_rate_batch"] == 0.0    # empty, not absent


def test_violation_rates_no_slo_population():
    out = _no_warnings(violation_rates, [_Req(t_slo=0.0)], classes=())
    assert "slo_violation_rate" not in out    # nothing carried an SLO


def test_class_blocks_zero_and_one_completed():
    reqs = [_Req(slo_class="interactive", ttft=0.3, jct=0.9)]
    out = _no_warnings(class_latency_blocks, reqs,
                       classes=("interactive", "batch"))
    assert out["completed_interactive"] == 1.0
    for p in (50, 95, 99):        # one sample: every percentile equals it
        assert out[f"ttft_interactive_p{p}"] == 0.3
        assert out[f"jct_interactive_p{p}"] == 0.9
    assert out["completed_batch"] == 0.0
    for p in (50, 95, 99):        # reported as None, never NaN or absent
        assert out[f"ttft_batch_p{p}"] is None
        assert out[f"jct_batch_p{p}"] is None


def test_latency_summary_without_classes_is_backwards_compatible():
    out = _no_warnings(latency_summary, [_Req()])
    assert out["ttft_p50"] == 0.5 and out["jct_p99"] == 1.0
    assert not any(k.startswith("completed_") for k in out)


def test_sim_result_empty_population():
    res = SimResult(requests=[], policy="u8")
    assert _no_warnings(res.mean_jct) == 0.0
    assert _no_warnings(res.p95_jct) == 0.0
    assert _no_warnings(res.mean_ttft) == 0.0
    s = _no_warnings(res.summary)
    assert s["completed"] == 0.0 and s["rejected"] == 0.0
    assert all(not (isinstance(v, float) and np.isnan(v))
               for v in s.values())


def test_sim_result_summary_reports_fully_shed_class():
    done = _Req(slo_class="interactive", ttft=0.3, jct=0.9)
    shed = _Req(slo_class="batch", chosen="rejected", ttft=0.0, jct=0.0)
    s = _no_warnings(SimResult(requests=[done, shed], policy="u8").summary)
    assert s["completed"] == 1.0 and s["rejected"] == 1.0
    assert s["completed_batch"] == 0.0
    assert s["ttft_batch_p50"] is None and s["jct_batch_p99"] is None
    assert s["slo_violation_rate_batch"] == 0.0
    assert s["ttft_interactive_p50"] == 0.3
    assert all(not (isinstance(v, float) and np.isnan(v))
               for v in s.values())
