import os
import sys

# Tests see the real single CPU device; only launch/dryrun.py forces 512.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session", autouse=True)
def _kv_sanitizer():
    """REPRO_SANITIZE=1 runs the whole suite with the runtime KV
    sanitizer installed (DESIGN.md §14): use-after-release,
    double-release, drain leaks and shared-tier clobbers fail loudly
    instead of surfacing as silent cross-request KV corruption.  CI runs
    the tier-1 suite once this way; local default is uninstrumented."""
    from repro.analysis import sanitize
    installed = sanitize.install_from_env()
    yield
    if installed:
        sanitize.uninstall()


@pytest.fixture(scope="session")
def kv_sample():
    from repro.core import KVCache
    return KVCache.random(num_layers=4, kv_heads=4, seq=160, head_dim=64, seed=0)


@pytest.fixture(scope="session")
def reference_model():
    """Session-cached tiny reference LM (trains on first ever use)."""
    from repro.core.quality import get_reference_model
    return get_reference_model()


@pytest.fixture(scope="session")
def synthetic_profiles():
    """A spread of plausible profiles for controller tests (no model runs)."""
    from repro.core.profiles import Profile
    from repro.core.strategy import StrategyConfig
    rng = np.random.default_rng(7)
    out = []
    for i in range(24):
        cr = float(rng.uniform(1.5, 9.0))
        s = float(rng.uniform(2e8, 2e10))
        q = {w: float(np.clip(1.02 - 0.006 * cr**1.5 + rng.normal(0, 0.01),
                              0, 1.0))
             for w in ("mathlike", "codelike", "qalike", "summlike")}
        out.append(Profile(
            StrategyConfig(key_bits=2 + (i % 7), value_bits=2 + ((i + 3) % 7),
                           group_size=(32, 64, 128)[i % 3]),
            cr=cr, s_enc=2 * s, s_dec=2 * s, quality=q))
    return out
