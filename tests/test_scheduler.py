"""Continuous scheduler: admission, SLO-class priority, shared sim path."""
import numpy as np
import pytest

from repro.serving import (
    GBPS,
    BandwidthTrace,
    ContinuousScheduler,
    NoCompressionPolicy,
    PrefixKVStore,
    Request,
    SchedulerConfig,
    SimConfig,
    Simulator,
    StaticPolicy,
    WorkloadMix,
    priority_key,
)


def _req(rid, arrival=0.0, slo_class="standard", t_slo=0.0):
    return Request(rid=rid, workload="qalike", arrival=arrival,
                   ctx_tokens=128, out_tokens=8, kv_bytes=1e6,
                   t_slo=t_slo, slo_class=slo_class)


# ---------------------------------------------------------------------------
# Pure policy layer
# ---------------------------------------------------------------------------
def test_priority_orders_slo_classes_then_slack_then_fifo():
    cfg = SchedulerConfig(aging_s=0.0)
    inter = _req(0, arrival=3.0, slo_class="interactive")
    tight = _req(1, arrival=1.0, slo_class="standard", t_slo=2.0)
    loose = _req(2, arrival=0.0, slo_class="standard", t_slo=50.0)
    batch = _req(3, arrival=0.0, slo_class="batch")
    order = sorted([batch, loose, tight, inter],
                   key=lambda r: priority_key(r, now=4.0, cfg=cfg))
    assert [r.rid for r in order] == [0, 1, 2, 3]


def test_aging_promotes_starved_batch_requests():
    cfg = SchedulerConfig(aging_s=5.0)
    old_batch = _req(0, arrival=0.0, slo_class="batch")
    fresh_inter = _req(1, arrival=59.0, slo_class="interactive")
    # after 60s the batch request has been promoted past interactive
    assert priority_key(old_batch, 60.0, cfg) < priority_key(fresh_inter,
                                                             60.0, cfg)


def test_admission_bounds_queue_and_sheds_load():
    sched = ContinuousScheduler(SchedulerConfig(max_queue=4))
    admitted = [sched.submit(_req(i, t_slo=1.0), now=0.0) for i in range(10)]
    assert sum(admitted) == 4 and sched.queue_depth == 4
    assert sched.admission.rejected == 6
    rejected = [r for ok, r in zip(admitted, range(10)) if not ok]
    assert len(rejected) == 6


def test_iteration_level_prefill_admission_respects_slots():
    cfg = SchedulerConfig(max_slots=3, max_prefills_per_step=2, max_queue=64)
    sched = ContinuousScheduler(cfg)
    for i in range(8):
        sched.submit(_req(i), now=0.0)
    first = sched.next_prefills(now=0.0)
    assert len(first) == 2 and sched.in_flight == 2
    second = sched.next_prefills(now=0.0)   # only 1 slot left
    assert len(second) == 1 and sched.in_flight == 3
    assert sched.next_prefills(now=0.0) == []  # saturated
    sched.finish(first[0].rid)
    assert len(sched.next_prefills(now=0.0)) == 1


def test_pop_next_is_priority_not_fifo():
    sched = ContinuousScheduler(SchedulerConfig(aging_s=0.0))
    sched.submit(_req(0, slo_class="batch"), now=0.0)
    sched.submit(_req(1, slo_class="interactive"), now=0.0)
    sched.submit(_req(2, slo_class="standard"), now=0.0)
    assert sched.pop_next(0.0).rid == 1
    assert sched.pop_next(0.0).rid == 2
    assert sched.pop_next(0.0).rid == 0


# ---------------------------------------------------------------------------
# Shared code path: scheduler + store driving the event simulator
# ---------------------------------------------------------------------------
def test_sim_scheduled_priority_helps_interactive_under_overload():
    """Overloaded PD cluster: interactive class must see lower JCT than
    batch when the shared scheduler orders dispatch, and the gap must be
    driven by scheduling (same workloads, same nodes)."""
    mk = lambda: WorkloadMix(
        rate=40.0, seed=7, q_min=0.0,
        slo_class_mix={"interactive": 0.5, "batch": 0.5}).generate(80)
    cfg = SimConfig(n_prefill=1, n_decode=1, prefill_tok_s=4000.0)
    trace = BandwidthTrace.constant(1 * GBPS)
    res = Simulator(cfg, NoCompressionPolicy(), trace, mk(),
                    scheduler=SchedulerConfig(max_queue=10_000,
                                              aging_s=0.0)).run()
    jct = {c: np.mean([r.jct for r in res.completed() if r.slo_class == c])
           for c in ("interactive", "batch")}
    assert jct["interactive"] < jct["batch"]
    assert len(res.completed()) == 80  # nothing lost


def test_sim_scheduled_admission_rejects_overload():
    mk = WorkloadMix(rate=200.0, seed=3, q_min=0.0).generate(60)
    cfg = SimConfig(n_prefill=1, prefill_tok_s=2000.0)
    res = Simulator(cfg, NoCompressionPolicy(),
                    BandwidthTrace.constant(1 * GBPS), mk,
                    scheduler=SchedulerConfig(max_queue=5)).run()
    assert len(res.rejected()) > 0
    assert len(res.rejected()) + len(res.completed()) == 60
    # rejected requests don't pollute latency metrics
    assert np.isfinite(res.jct()).all()


def test_sim_scheduled_zero_queue_rejects_everything():
    """max_queue=0 sheds every request without crashing the dispatch loop."""
    reqs = WorkloadMix(rate=5.0, seed=2, q_min=0.0).generate(10)
    res = Simulator(SimConfig(), NoCompressionPolicy(),
                    BandwidthTrace.constant(1 * GBPS), reqs,
                    scheduler=SchedulerConfig(max_queue=0)).run()
    assert len(res.rejected()) == 10 and not res.completed()


def test_sim_pool_store_hits_beat_cold_and_evictions_cause_misses(
        synthetic_profiles):
    """With a real store, the first user of a prefix pays recompute and
    later users hit; shrinking capacity forces evictions and misses."""
    prof = max(synthetic_profiles, key=lambda p: p.cr)
    # Arrivals must be slower than prefill: pool entries only become
    # visible once their write completes, so back-to-back arrivals would
    # all miss (no time-travel hits).
    mk = lambda seed: WorkloadMix(rate=0.05, seed=seed, q_min=0.0,
                                  prefix_hit_rate=0.8).generate(50)
    cfg = SimConfig(scenario="pool", prefill_tok_s=3000.0)
    trace = BandwidthTrace.constant(1 * GBPS)

    big = PrefixKVStore(capacity_bytes=1 << 34, block=1)
    res = Simulator(cfg, StaticPolicy(prof, "s"), trace, mk(0),
                    store=big).run()
    # full hits only: partial hits carry both comm and top-up prefill
    hits = [r for r in res.completed() if r.breakdown.get("comm", 0) > 0
            and r.breakdown.get("prefill", 0) == 0]
    colds = [r for r in res.completed() if r.breakdown.get("prefill", 0) > 0
             and r.breakdown.get("comm", 0) == 0]
    partials = [r for r in res.completed() if r.breakdown.get("comm", 0) > 0
                and r.breakdown.get("prefill", 0) > 0]
    assert hits and colds
    assert np.mean([r.ttft for r in hits]) < np.mean([r.ttft for r in colds])
    assert big.stats.hits == len(hits) + len(partials)
    assert big.stats.evictions == 0

    small = PrefixKVStore(capacity_bytes=int(4e8), block=1)
    res2 = Simulator(cfg, StaticPolicy(prof, "s"), trace, mk(0),
                     store=small).run()
    assert small.stats.evictions > 0
    assert small.stats.hits < big.stats.hits  # evictions turned hits to misses


def test_sim_pool_partial_prefix_hit_pays_topup_prefill(synthetic_profiles):
    """An entry covering only part of the prompt is fetched AND the
    uncovered suffix is top-up prefilled — TTFT sits between a full hit
    and a cold recompute."""
    prof = max(synthetic_profiles, key=lambda p: p.cr)
    store = PrefixKVStore(capacity_bytes=1 << 34, block=16)
    full_key = tuple(range(64))
    store.put(full_key[:32], prof, int(1e6), kv_bytes=5e6, now=0.0)

    def req(rid, key):
        from repro.serving import Request
        return Request(rid=rid, workload="qalike", arrival=0.0,
                       ctx_tokens=2000, out_tokens=8, kv_bytes=1e7,
                       q_min=0.0, prefix_key=key)

    cfg = SimConfig(scenario="pool", prefill_tok_s=500.0)
    trace = BandwidthTrace.constant(1 * GBPS)
    partial = Simulator(cfg, StaticPolicy(prof, "s"), trace,
                        [req(0, full_key)], store=store).run().requests[0]
    assert partial.breakdown["comm"] > 0          # fetched the prefix
    assert partial.breakdown["prefill"] > 0       # topped-up the suffix
    # roughly half the prompt recomputed: cheaper than full cold prefill
    t_cold = 2000 / 500.0
    assert partial.breakdown["prefill"] < t_cold
    assert partial.ttft < t_cold + 0.5


def test_sim_scheduled_aging_prevents_batch_starvation():
    """Starvation-freedom (ISSUE 5 satellite): a batch request submitted
    behind a continuous interactive flood is admitted once aging promotes
    it past the flood — long before the flood drains — while with aging
    disabled it is served dead last.  Deterministic: one prefill node,
    constant trace, no faults."""
    def run(aging_s):
        reqs = [Request(rid=0, workload="qalike", arrival=0.0,
                        ctx_tokens=1000, out_tokens=1, kv_bytes=1e5,
                        q_min=0.0, slo_class="batch")]
        reqs += [Request(rid=1 + i, workload="qalike", arrival=0.05 * i,
                         ctx_tokens=1000, out_tokens=1, kv_bytes=1e5,
                         q_min=0.0, slo_class="interactive")
                 for i in range(60)]
        res = Simulator(
            SimConfig(scenario="pd", n_prefill=1, n_decode=1,
                      prefill_tok_s=1000.0, decode_tok_s=100.0),
            NoCompressionPolicy(), BandwidthTrace.constant(1 * GBPS),
            reqs, scheduler=SchedulerConfig(max_queue=1000,
                                            aging_s=aging_s)).run()
        assert len(res.completed()) == 61        # nothing starved FOREVER
        batch = next(r for r in res.requests if r.slo_class == "batch")
        last_inter = max(r.done for r in res.requests
                         if r.slo_class == "interactive")
        return batch.done, last_inter

    aged_done, last_inter = run(aging_s=1.0)
    # Aging promotes one class per second: the batch request overtakes
    # every interactive that arrived >2 s after it, so it is served
    # mid-flood rather than after the ~60 s backlog drains.
    assert aged_done < 30.0
    assert aged_done < last_inter
    starved_done, last_inter0 = run(aging_s=0.0)
    assert starved_done > 55.0                   # served dead last
    assert starved_done > last_inter0 - 2.0
    assert starved_done > 2 * aged_done
