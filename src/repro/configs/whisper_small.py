"""Config alias for --arch whisper-small (see repro/configs/archs.py)."""
from repro.configs import get_config

CONFIG = get_config("whisper-small")
