"""Trip-count-aware HLO cost walker: validated against XLA on loop-free
programs and against trip×body on scans."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze_hlo_text, xla_cost_analysis


def _cost(fn, *avals):
    comp = jax.jit(fn).lower(*avals).compile()
    return analyze_hlo_text(comp.as_text()), comp


def test_matmul_exact():
    m = 256
    a = jax.ShapeDtypeStruct((m, m), jnp.float32)
    c, comp = _cost(lambda a, b: a @ b, a, a)
    assert c.flops == xla_cost_analysis(comp)["flops"] == 2 * m**3
    assert c.bytes == xla_cost_analysis(comp)["bytes accessed"]


def test_scan_multiplies_trip_count():
    m, n = 128, 10
    def f(x, ws):
        y, _ = jax.lax.scan(lambda x, w: (jnp.tanh(x @ w), None), x, ws)
        return y
    c, comp = _cost(f, jax.ShapeDtypeStruct((m, m), jnp.float32),
                    jax.ShapeDtypeStruct((n, m, m), jnp.float32))
    expected = n * 2 * m**3
    assert abs(c.flops - expected) / expected < 0.02
    # XLA's own analysis counts the body once — the bug we fix
    assert xla_cost_analysis(comp)["flops"] < expected / (n - 1)


def test_nested_scan():
    m = 64
    def g(x, ws):
        def outer(x, w3):
            y, _ = jax.lax.scan(lambda x, w: (x @ w, None), x, w3)
            return y, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y
    c, _ = _cost(g, jax.ShapeDtypeStruct((m, m), jnp.float32),
                 jax.ShapeDtypeStruct((4, 3, m, m), jnp.float32))
    expected = 12 * 2 * m**3
    assert abs(c.flops - expected) / expected < 0.02


def test_bf16_dot():
    m = 128
    a = jax.ShapeDtypeStruct((m, m), jnp.bfloat16)
    c, _ = _cost(lambda a, b: a @ b, a, a)
    assert abs(c.flops - 2 * m**3) / (2 * m**3) < 0.02


def test_conv_flops_depthwise():
    # depthwise causal conv like the mamba front-end
    b, ch, s, k = 2, 16, 64, 4
    def f(x, w):
        return jax.lax.conv_general_dilated(
            x[:, :, None, :], w[:, None, None, :], (1, 1), "VALID",
            feature_group_count=ch)
    c, _ = _cost(f, jax.ShapeDtypeStruct((b, ch, s), jnp.float32),
                 jax.ShapeDtypeStruct((ch, k), jnp.float32))
    out_elems = b * ch * (s - k + 1)
    expected = 2 * out_elems * k
    assert c.flops <= expected * 2 and c.flops >= out_elems  # right order


def test_collectives_counted_zero_on_single_device():
    m = 64
    c, _ = _cost(lambda a, b: a @ b,
                 jax.ShapeDtypeStruct((m, m), jnp.float32),
                 jax.ShapeDtypeStruct((m, m), jnp.float32))
    assert c.coll_bytes == 0.0
