"""Speculative + lookahead decoding (DESIGN.md §15).

The load-bearing guarantee is *token-exactness*: with greedy
verification, a speculative run must emit exactly the tokens a plain
run of the SAME model emits — drafts only change how many serial decode
steps it takes.  The pinned scenario of ``_runtime_scenario`` is run
spec-on vs spec-off across k ∈ {2, 4} × {dense, paged} × {pool, pd};
the fixture-parity variant additionally pins against the PR-1 tokens
when the trained reference model matches the fixture's digest.

The n-gram proposer carries the exactness tests: its drafts reuse the
target's own committed history, so every accepted position's KV row was
computed from the token the target itself emitted.  The two-model path
is exercised for dataflow (deep accepts, multi-token commits) with a
high-agreement assertion instead — committed rows from a width-(k+1)
verify can differ from sequentially-written rows by ~1 bf16 ulp (the
online-softmax merge associates differently), which can flip greedy
argmax near-ties far downstream; see DESIGN.md §15.
"""
import pytest

from _runtime_scenario import build_runtime, run_scenario
from repro.serving.speculative import NGramDraft, accept_length


# ---------------------------------------------------------------------------
# Pure units: accept rule + n-gram proposer
# ---------------------------------------------------------------------------
def test_accept_length_is_longest_matching_prefix():
    assert accept_length([], [7]) == 0
    assert accept_length([3, 5], [3, 5, 9]) == 2
    assert accept_length([3, 5], [3, 6, 9]) == 1
    assert accept_length([4, 5], [3, 5, 9]) == 0


def test_ngram_draft_proposes_most_recent_continuation():
    d = NGramDraft(max_ngram=2)
    d.start(0, 42, [1, 2, 9], first=1)
    # history 1 2 9 1 — suffix 1-gram "1" last continued with 2
    out = d.propose_all([(0, 42, 1, 4)], {0: 3})
    assert out[0] == [2, 9, 1]
    # 2-gram beats 1-gram: after committing 2, suffix "1 2" matches pos 0-1
    d.commit(0, 42, [2])
    out = d.propose_all([(0, 42, 2, 5)], {0: 2})
    assert out[0] == [9, 1]
    # unseen suffix -> no drafts; the slot decodes plainly that iteration
    d.commit(0, 42, [77])
    assert d.propose_all([(0, 42, 77, 6)], {0: 4})[0] == []


def test_ngram_draft_state_is_per_request():
    d = NGramDraft()
    d.start(0, 1, [5, 6], first=5)
    d.start(1, 2, [8, 8], first=8)
    out = d.propose_all([(0, 1, 5, 3), (1, 2, 8, 3)], {0: 2, 1: 2})
    assert out[0] == [6, 5]
    assert out[1] == [8]    # continuation truncated at end of history
    d.stop(0, 1)
    assert d.propose_all([(0, 1, 5, 3)], {0: 2})[0] == []


# ---------------------------------------------------------------------------
# Token-exactness on the real tiny model
# ---------------------------------------------------------------------------
_BASELINES = {}


def _baseline(reference_model, mode, paged):
    key = (mode, paged)
    if key not in _BASELINES:
        rt = build_runtime(reference_model, mode=mode, paged=paged)
        _BASELINES[key] = run_scenario(rt)
    return _BASELINES[key]


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["pool", "pd"])
@pytest.mark.parametrize("paged", [False, True])
@pytest.mark.parametrize("k", [2, 4])
def test_ngram_speculation_token_exact_vs_plain_decode(
        reference_model, mode, paged, k):
    base = _baseline(reference_model, mode, paged)
    rt = build_runtime(reference_model, mode=mode, paged=paged, spec_k=k)
    out = run_scenario(rt)
    assert out == base
    # speculation actually engaged and compressed serial steps
    done = rt.completed
    steps = sum(r.verify_steps for r in done)
    committed = sum(r.spec_committed for r in done)
    assert steps > 0 and committed > steps
    # the summary carries the acceptance block (satellite 1)
    s = rt.summary()
    assert s["spec_tokens_per_step"] == pytest.approx(committed / steps)
    assert 0.0 <= s["spec_accept_rate"] <= 1.0


@pytest.mark.slow
@pytest.mark.parametrize("k", [2, 4])
def test_speculative_parity_with_pr1_fixture(reference_model, k):
    """Same pin as test_token_exact_parity_with_pr1_fixture, speculation
    on: the PR-1 per-slot loop's tokens, bit for bit."""
    import json
    from _runtime_scenario import FIXTURE, params_digest
    fix = json.loads(FIXTURE.read_text())
    rt = build_runtime(reference_model, spec_k=k)
    if params_digest(rt.params) != fix["params_digest"]:
        pytest.skip("reference model differs from the fixture's "
                    "(e.g. CI trains a smaller REPRO_REF_STEPS model)")
    out = run_scenario(rt)
    for rid, rec in fix["outputs"].items():
        assert out[rid]["tokens"] == rec["tokens"], rid


@pytest.mark.slow
def test_spec_k_zero_is_the_plain_path(reference_model):
    """k = 0 must not merely produce the same tokens — it must BE the
    non-speculative path: legacy arena geometry, zero verify steps, no
    speculation keys in the summary."""
    from repro.serving.engine import RuntimeConfig
    assert RuntimeConfig(seq=64, decode_tokens=6).arena_max_len == \
        RuntimeConfig(seq=64, decode_tokens=6, spec_k=0).arena_max_len
    rt = build_runtime(reference_model, spec_k=0)
    out = run_scenario(rt)
    assert out == _baseline(reference_model, "pool", False)
    assert all(r.verify_steps == 0 and r.spec_committed == 0
               for r in rt.completed)
    assert "spec_tokens_per_step" not in rt.summary()


@pytest.mark.slow
def test_model_draft_path_dataflow(reference_model):
    """Two-model path with the target as its own draft: acceptance is
    near-1, so verify steps commit multi-token runs and the serial step
    count collapses.  Exactness is asserted only to high agreement — the
    bf16 merge-ulp caveat above — plus first-token equality per request
    (prefill is untouched by speculation)."""
    base = _baseline(reference_model, "pool", False)
    rt = build_runtime(reference_model, spec_k=4, spec_kind="model")
    out = run_scenario(rt)
    assert set(out) == set(base)
    agree = total = 0
    for rid, rec in base.items():
        a, b = rec["tokens"], out[rid]["tokens"]
        assert len(a) == len(b)
        assert a[0] == b[0], rid
        agree += sum(int(x == y) for x, y in zip(a, b))
        total += len(a)
    assert agree / total >= 0.9, (agree, total)
    done = rt.completed
    steps = sum(r.verify_steps for r in done)
    committed = sum(r.spec_committed for r in done)
    assert committed / steps > 2.0   # deep accepts, not 1-token crawl

# ---------------------------------------------------------------------------
# Controller: adaptive speculation length
# ---------------------------------------------------------------------------
def _spec_controller(cands=(0, 2, 4), **kw):
    from repro.controller import ServiceAwareController
    return ServiceAwareController({}, spec_candidates=cands, **kw)


def _ctx(workload="qalike", route="", decode_time=1.0):
    from repro.controller import ServiceContext
    return ServiceContext(workload=workload, bandwidth=1e9, t_slo=0.0,
                          q_min=0.0, decode_time=decode_time, route=route)


def test_tokens_per_step_model_is_the_geometric_series():
    from repro.controller import expected_tokens_per_step as tps
    assert tps(0, 0.7) == 1.0
    assert tps(3, 0.0) == 1.0
    assert tps(2, 1.0) == 3.0
    assert tps(2, 0.5) == pytest.approx(1 + 0.5 + 0.25)


def test_controller_falls_back_to_plain_decode_at_zero_accept():
    c = _spec_controller(spec_accept_prior=0.0)
    assert c.select(_ctx()).spec_k == 0


def test_controller_picks_max_k_at_high_accept():
    c = _spec_controller(spec_accept_prior=1.0)
    assert c.select(_ctx()).spec_k == 4
    # unknown decode time still ranks candidates (scale-free objective)
    assert c.select(_ctx(decode_time=0.0)).spec_k == 4


def test_controller_verify_overhead_caps_k():
    from repro.controller import speculative_decode_latency as sdl
    # at accept .5, one extra draft buys <.25 tokens past k=2 but costs
    # 10% verify overhead per draft position -> k should not run away
    lats = {k: sdl(1.0, k, 0.5, verify_overhead=0.1) for k in (0, 2, 4, 8)}
    assert min(lats, key=lats.get) in (2, 4)
    assert lats[8] > lats[4]


def test_accept_rate_is_learned_per_workload_route():
    c = _spec_controller(spec_accept_prior=0.5, spec_accept_alpha=0.2)
    assert c.accept_rate("codelike", "p0->d0") == 0.5
    c.observe_accept("codelike", "p0->d0", 1.0)
    assert c.accept_rate("codelike", "p0->d0") == 1.0   # first obs replaces
    c.observe_accept("codelike", "p0->d0", 0.0)
    assert c.accept_rate("codelike", "p0->d0") == pytest.approx(0.8)
    # other (workload, route) keys untouched
    assert c.accept_rate("codelike", "p0->d1") == 0.5
    assert c.accept_rate("qalike", "p0->d0") == 0.5
    # learned rate drives k-selection on that route only
    lo = _spec_controller(spec_accept_prior=0.5)
    for _ in range(30):
        lo.observe_accept("qalike", "", 0.0)
    assert lo.select(_ctx()).spec_k == 0
    assert lo.select(_ctx(route="p0->d9")).spec_k > 0


@pytest.mark.slow
def test_adaptive_spec_k_flows_controller_to_slots(reference_model):
    """cfg.spec_adaptive: each slot's draft budget is the controller
    decision's spec_k (capped at cfg.spec_k), and _finish feeds realized
    accept rates back through observe_accept."""
    from repro.controller import Decision
    from repro.core.profiles import Profile
    from repro.core.strategy import StrategyConfig

    class SpySpecController:
        def __init__(self, profile, spec_k):
            self._profile = profile
            self._spec_k = spec_k
            self.accepts = []

        def select(self, ctx):
            return Decision(self._profile, 0, 0, 0.0, spec_k=self._spec_k)

        def observe(self, ctx, decision, latency):
            pass

        def observe_accept(self, workload, route, rate):
            self.accepts.append((workload, route, rate))

    profile = Profile(
        StrategyConfig(quantizer="uniform", key_bits=8, value_bits=8,
                       granularity="per_channel"),
        cr=2.0, s_enc=5e8, s_dec=5e8)
    spy = SpySpecController(profile, spec_k=7)   # above the cap
    rt = build_runtime(reference_model, spec_k=3, spec_adaptive=True)
    rt.static_profile = None
    rt.controller = spy
    for pw in rt.prefill_workers:
        pw.controller = spy
    out = run_scenario(rt)
    assert out == _baseline(reference_model, "pool", False)
    # the controller's pick was capped at cfg.spec_k
    assert all(r.spec_k == 3 for r in rt.completed if not r.pool_hit)
    # realized accept rates fed back for every request that offered drafts
    offered = [r for r in rt.completed if r.drafts_offered > 0]
    assert offered and len(spy.accepts) == len(offered)
    assert all(0.0 <= rate <= 1.0 for _, _, rate in spy.accepts)


# ---------------------------------------------------------------------------
# Metrics: the acceptance block
# ---------------------------------------------------------------------------
class _Rec:
    def __init__(self, **kw):
        self.ttft = kw.pop("ttft", 0.1)
        self.jct = kw.pop("jct", 0.2)
        self.slo_class = kw.pop("slo_class", "standard")
        self.t_slo = 0.0
        self.slo_violated = False
        self.__dict__.update(kw)


def test_speculation_stats_aggregates_per_class():
    from repro.serving.metrics import latency_summary, speculation_stats
    reqs = [
        _Rec(slo_class="interactive", verify_steps=4, spec_committed=12,
             drafts_offered=12, drafts_accepted=8),
        _Rec(slo_class="batch", verify_steps=2, spec_committed=2,
             drafts_offered=4, drafts_accepted=0),
        _Rec(slo_class="batch"),   # non-speculative record contributes 0
    ]
    s = speculation_stats(reqs, classes=("interactive", "batch", "standard"))
    assert s["spec_tokens_per_step"] == pytest.approx(14 / 6)
    assert s["spec_accept_rate"] == pytest.approx(8 / 16)
    assert s["spec_tokens_per_step_interactive"] == pytest.approx(3.0)
    assert s["spec_tokens_per_step_batch"] == pytest.approx(1.0)
    assert s["spec_tokens_per_step_standard"] is None
    # wired into the shared summary block
    full = latency_summary(reqs, classes=("interactive", "batch"))
    assert full["spec_tokens_per_step"] == s["spec_tokens_per_step"]


def test_speculation_stats_silent_without_speculation():
    from repro.serving.metrics import latency_summary, speculation_stats
    reqs = [_Rec(), _Rec(verify_steps=0, spec_committed=0)]
    assert speculation_stats(reqs) == {}
    assert not any(k.startswith("spec_") for k in latency_summary(reqs))


# ---------------------------------------------------------------------------
# Simulator: deterministic acceptance model
# ---------------------------------------------------------------------------
def _sim_profile():
    from repro.core.profiles import Profile
    from repro.core.strategy import StrategyConfig
    return Profile(StrategyConfig(quantizer="uniform", key_bits=8,
                                  value_bits=8, granularity="per_channel"),
                   cr=2.0, s_enc=5e8, s_dec=5e8)


def _sim_requests(n=40, seed=3):
    import numpy as np
    from repro.serving.request import Request
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    for i in range(n):
        t += float(rng.exponential(0.05))
        out.append(Request(rid=i, workload="qalike", arrival=t,
                           ctx_tokens=int(rng.integers(200, 2000)),
                           out_tokens=int(rng.integers(20, 200)),
                           kv_bytes=float(rng.integers(1, 8)) * 1e6))
    return out


def _sim(cfg, needs_ctx=False):
    from repro.serving.network import BandwidthTrace, GBPS
    from repro.serving.simulator import Simulator, StaticPolicy
    pol = StaticPolicy(_sim_profile(), "u8")
    pol.needs_ctx = needs_ctx
    return Simulator(cfg, pol, BandwidthTrace.constant(2 * GBPS),
                     _sim_requests())


def test_sim_spec_k_zero_is_bit_identical():
    from repro.serving.simulator import SimConfig
    a = _sim(SimConfig(scenario="pd", n_prefill=2, n_decode=2, seed=0)).run()
    b = _sim(SimConfig(scenario="pd", n_prefill=2, n_decode=2, seed=0,
                       spec_k=0, spec_accept=0.9)).run()
    for x, y in zip(a.requests, b.requests):
        assert x.done == y.done and x.breakdown == y.breakdown


def test_sim_speculation_deterministic_and_sums_to_jct():
    from repro.serving.simulator import SimConfig, spec_tokens_per_step
    cfg = SimConfig(scenario="pd", n_prefill=2, n_decode=2, seed=0,
                    straggler_sigma=0.15, spec_k=4, spec_accept=0.6)
    r1, r2 = _sim(cfg).run(), _sim(cfg).run()
    for x, y in zip(r1.requests, r2.requests):
        assert x.done == y.done and x.breakdown == y.breakdown
    base = _sim(SimConfig(scenario="pd", n_prefill=2, n_decode=2, seed=0,
                          straggler_sigma=0.15)).run()
    for r in r1.requests:     # breakdown identity survives speculation
        assert sum(r.breakdown.values()) == pytest.approx(r.jct, abs=1e-9)
    dec = sum(r.breakdown["decode"] for r in r1.requests)
    dec0 = sum(r.breakdown["decode"] for r in base.requests)
    assert dec < dec0         # speculation shortens the decode stream
    # acceptance jitter is a pure hash of (seed, rid): no rng consumed
    tps = [spec_tokens_per_step(cfg, i) for i in range(50)]
    assert tps == [spec_tokens_per_step(cfg, i) for i in range(50)]
    assert all(1.0 <= t <= cfg.spec_k + 1 for t in tps)
    assert len(set(tps)) > 1  # requests genuinely differ


def test_sim_fast_pd_bit_parity_holds_with_speculation():
    from repro.serving.simulator import SimConfig
    cfg = SimConfig(scenario="pd", n_prefill=3, n_decode=2, seed=0,
                    straggler_sigma=0.15, spec_k=4, spec_accept=0.6)
    fast, slow = _sim(cfg), _sim(cfg, needs_ctx=True)
    assert fast._fast_pd_eligible() and not slow._fast_pd_eligible()
    rf, rs = fast.run(), slow.run()
    for a, b in zip(rf.requests, rs.requests):
        assert a.done == b.done and a.ttft == b.ttft
        assert a.breakdown == b.breakdown, a.rid


# ---------------------------------------------------------------------------
# Sanitizer: speculative rollback accounting (satellite 2)
# ---------------------------------------------------------------------------
def test_sanitizer_silent_on_legal_speculative_rollback():
    from repro.analysis import sanitize
    from repro.core.kvcache import PageTable
    assert not sanitize.enabled() or sanitize.uninstall() is None
    sanitize.install()
    try:
        pt = PageTable(num_pages=32, page_size=8)
        pt.ensure(0, 20)                       # 3 committed pages
        pt.ensure(0, 20 + 13)                  # +2 pages for 13 drafts
        freed = pt.release_tail(0, 21)         # rollback to 21 committed
        assert len(freed) == 2
        assert pt.release_tail(0, 21) == []    # idempotent re-rollback: ok
        pt.check()
        pt.release(0)
    finally:
        sanitize.uninstall()


def test_sanitizer_catches_double_released_rollback_page():
    from repro.analysis import sanitize
    from repro.core.kvcache import PageTable
    sanitize.install()
    try:
        pt = PageTable(num_pages=32, page_size=8)
        pt.ensure(0, 24)
        freed = pt.release_tail(0, 9)          # 2 tail pages to the pool
        # buggy rollback path: the slot still claims a page it freed
        pt.pages[0].append(freed[0])
        with pytest.raises(sanitize.SanitizerError) as ei:
            pt.release_tail(0, 9)
        assert ei.value.kind == "double-release"
    finally:
        sanitize.uninstall()


@pytest.mark.slow
def test_sanitizer_silent_under_paged_speculative_run(reference_model):
    """End-to-end: a paged speculative run under the installed sanitizer
    (ensure -> verify -> release_tail rollback every step) must complete
    with zero findings and drain clean."""
    from repro.analysis import sanitize
    sanitize.install()
    try:
        rt = build_runtime(reference_model, paged=True, spec_k=4)
        out = run_scenario(rt)
        assert out == _baseline(reference_model, "pool", True)
    finally:
        sanitize.uninstall()
