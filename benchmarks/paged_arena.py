"""Paged KV arena benchmark (ISSUE 7, EXPERIMENTS.md §Perf #9).

Two acceptance properties of the paged decode arena (DESIGN.md §12):

* **Capacity** — at a FIXED arena HBM budget, quantized-resident pages
  (int4/int8 codes + per-group fp16 scales consumed in place by the
  fused dequant-attention kernel) hold ≥2x more concurrently decodable
  slots than dense bf16 pages (the int4 layouts; int8 lands near the
  raw 2x code shrink minus scale overhead).  Pure byte accounting via
  :meth:`PageTable.page_bytes_fp16` / :meth:`page_bytes_quant` — no
  timing, fully deterministic.

* **TTFT** — the real 1x1 ServingRuntime (virtual clock) serving a
  paged-eligible profile: with ``RuntimeConfig.paged`` the pool hit's
  materialized decompress leaves the TTFT breakdown (~0, the pages feed
  the fused kernel directly) while the dense runtime still pays
  V/s_dec; per-request breakdowns must keep summing to JCT in both.
  All reported numbers are virtual-clock quantities (byte counts /
  configured rates), so the grid is machine-independent.

Determinism contract: the payload is a pure function of the
configuration — no wall-clock values enter the JSON, floats are rounded
to 6 significant digits.  The grid is committed at
``BENCH_paged_arena.json``; CI regenerates it and fails when the
committed copy is stale (``python -m benchmarks.paged_arena --check``).
Refresh with ``python -m benchmarks.paged_arena --smoke --write``.
"""
from __future__ import annotations

import argparse
import json
import math
import os
from typing import Dict, Optional

from benchmarks.common import emit, write_json
from repro.core.kvcache import PageTable
from repro.core.profiles import Profile
from repro.core.strategy import StrategyConfig, paged_eligible
from repro.serving.network import GBPS, BandwidthTrace

BENCH_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_paged_arena.json")

# tiny-lm decode-arena geometry (engine defaults: seq=64 + 6 decode + 2)
L, H, D = 4, 2, 32
MAX_LEN, PAGE_SIZE = 72, 8
N_DENSE_SLOTS = 16
QUANT_LAYOUTS = ((8, 32), (4, 32), (4, 16))   # (bits, channel group)
WORKLOAD_CYCLE = ("qalike", "codelike", "mathlike", "summlike")


# ---------------------------------------------------------------------------
# Part 1: slots at a fixed HBM budget (analytic byte accounting)
# ---------------------------------------------------------------------------
def capacity_grid() -> Dict[str, object]:
    pps = MAX_LEN // PAGE_SIZE
    fp16_page = PageTable.page_bytes_fp16(PAGE_SIZE, H, D, L)
    budget = N_DENSE_SLOTS * pps * fp16_page
    rows = []
    for bits, group in QUANT_LAYOUTS:
        q_page = PageTable.page_bytes_quant(PAGE_SIZE, H, D, L,
                                            bits=bits, group=group)
        slots = int((budget // q_page) // pps)
        rows.append({
            "bits": bits, "group": group,
            "page_bytes_fp16": int(fp16_page),
            "page_bytes_quant": int(q_page),
            "slots_dense": N_DENSE_SLOTS,
            "slots_quant": slots,
            "slots_ratio": slots / N_DENSE_SLOTS,
        })
    return {"hbm_budget_bytes": int(budget), "pages_per_slot": pps,
            "layouts": rows}


# ---------------------------------------------------------------------------
# Part 2: TTFT breakdown, paged vs dense runtime (virtual clock)
# ---------------------------------------------------------------------------
def _eligible_profile() -> Profile:
    p = Profile(
        StrategyConfig(quantizer="uniform", key_bits=8, value_bits=8,
                       granularity="per_token", symmetric=True,
                       group_size=32),
        cr=2.0, s_enc=5e8, s_dec=5e8)
    assert paged_eligible(p.strategy)
    return p


def ttft_grid() -> Dict[str, object]:
    from repro.serving import SchedulerConfig
    from repro.serving.engine import RuntimeConfig, ServingRuntime

    out: Dict[str, object] = {}
    for name, paged in (("dense", False), ("paged", True)):
        cfg = RuntimeConfig(seq=64, decode_tokens=6, prefill_tok_s=2000.0,
                            decode_tok_s=500.0, paged=paged,
                            page_size=PAGE_SIZE)
        rt = ServingRuntime(
            static_profile=_eligible_profile(), config=cfg,
            trace=BandwidthTrace.constant(1 * GBPS),
            scheduler=SchedulerConfig(max_slots=6, max_prefills_per_step=2,
                                      max_queue=32))
        # 4 writers, then 4 repeats of the same prompts => 4 pool hits
        for seed, w in enumerate(WORKLOAD_CYCLE):
            rt.submit(w, prompt_seed=seed)
            rt.step()
        rt.run()
        for seed, w in enumerate(WORKLOAD_CYCLE):
            rt.submit(w, prompt_seed=seed)
            rt.step()
        rt.run()
        hits = [r for r in rt.completed if r.pool_hit]
        colds = [r for r in rt.completed if not r.pool_hit]
        assert len(hits) == len(colds) == len(WORKLOAD_CYCLE), (
            len(hits), len(colds))
        for r in rt.completed:   # breakdowns must still sum to JCT
            gap = abs(sum(r.breakdown.values()) - r.jct)
            assert gap < 1e-9, (r.rid, r.breakdown, r.jct)
        mean = lambda vals: sum(vals) / len(vals)
        out[name] = {
            "n_hits": len(hits),
            "ttft_hit_mean": mean([r.ttft for r in hits]),
            "ttft_cold_mean": mean([r.ttft for r in colds]),
            "hit_decompress_mean": mean(
                [r.breakdown.get("decompress", 0.0) for r in hits]),
            "hit_comm_mean": mean(
                [r.breakdown.get("comm", 0.0) for r in hits]),
            "hit_wire_bytes": int(sum(r.wire_bytes for r in hits)),
        }
    return out


# ---------------------------------------------------------------------------
# Committed-JSON plumbing (same contract as benchmarks/trace_grid.py)
# ---------------------------------------------------------------------------
def _round(x, sig: int = 6):
    if isinstance(x, dict):
        return {k: _round(v, sig) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_round(v, sig) for v in x]
    if isinstance(x, bool) or not isinstance(x, float):
        return x
    if x == 0.0 or not math.isfinite(x):
        return x
    return round(x, sig - 1 - int(math.floor(math.log10(abs(x)))))


def build_grid(smoke: bool = True) -> Dict[str, object]:
    return _round({
        "version": 1,
        "smoke": bool(smoke),
        "geometry": {"num_layers": L, "kv_heads": H, "head_dim": D,
                     "max_len": MAX_LEN, "page_size": PAGE_SIZE},
        "capacity": capacity_grid(),
        "ttft": ttft_grid(),
    })


def _diff(a, b, path="") -> Optional[str]:
    if isinstance(a, dict) and isinstance(b, dict):
        for k in sorted(set(a) | set(b)):
            d = _diff(a.get(k), b.get(k), f"{path}.{k}")
            if d:
                return d
        return None
    if isinstance(a, list) and isinstance(b, list):
        if len(a) != len(b):
            return f"{path}: length {len(a)} != {len(b)}"
        for i, (x, y) in enumerate(zip(a, b)):
            d = _diff(x, y, f"{path}[{i}]")
            if d:
                return d
        return None
    if a != b:
        return f"{path}: {a!r} != {b!r}"
    return None


def check_against_committed(grid: Dict[str, object]) -> None:
    if not os.path.exists(BENCH_PATH):
        raise AssertionError(
            f"{BENCH_PATH} missing — generate it with "
            f"`python -m benchmarks.paged_arena --smoke --write`")
    with open(BENCH_PATH) as f:
        committed = json.load(f)
    d = _diff(_round(committed), grid)
    assert d is None, (
        f"BENCH_paged_arena.json is stale vs the current code at {d}; "
        f"refresh with `python -m benchmarks.paged_arena --smoke --write`")


def _assert_acceptance(grid: Dict[str, object]) -> None:
    # Capacity: every int4 layout fits ≥2x the dense slot count
    for row in grid["capacity"]["layouts"]:
        if row["bits"] == 4:
            assert row["slots_ratio"] >= 2.0, row
        assert row["slots_ratio"] > 1.0, row
    # TTFT: the paged hit path dropped its materialized decompress ...
    dense, paged = grid["ttft"]["dense"], grid["ttft"]["paged"]
    assert dense["hit_decompress_mean"] > 0, dense
    assert paged["hit_decompress_mean"] == 0.0, paged
    # ... and nothing else regressed: same bytes moved, faster first token
    assert paged["hit_wire_bytes"] == dense["hit_wire_bytes"]
    assert paged["ttft_hit_mean"] < dense["ttft_hit_mean"]


def _emit_rows(grid: Dict[str, object]) -> None:
    for row in grid["capacity"]["layouts"]:
        emit(f"paged_arena_capacity_int{row['bits']}_g{row['group']}", 0.0,
             f"slots={row['slots_quant']} vs dense={row['slots_dense']} "
             f"ratio={row['slots_ratio']:.2f}x "
             f"page_bytes={row['page_bytes_quant']}")
    for name in ("dense", "paged"):
        t = grid["ttft"][name]
        emit(f"paged_arena_ttft_{name}", 0.0,
             f"ttft_hit={t['ttft_hit_mean']*1e3:.3f}ms "
             f"ttft_cold={t['ttft_cold_mean']*1e3:.3f}ms "
             f"hit_decompress={t['hit_decompress_mean']*1e3:.3f}ms "
             f"n_hits={t['n_hits']}")


def run(smoke: bool = False, write: bool = False, check: bool = False,
        json_path: str = "") -> None:
    grid = build_grid(smoke=smoke or check)
    _emit_rows(grid)
    _assert_acceptance(grid)
    if smoke or check:
        # Determinism: a second build must be byte-identical (virtual
        # clock + analytic byte accounting, end to end).
        again = build_grid(smoke=True)
        d = _diff(grid, again)
        assert d is None, f"paged-arena grid is non-deterministic at {d}"
    if write:
        with open(BENCH_PATH, "w") as f:
            json.dump(grid, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"# wrote {BENCH_PATH}")
    elif smoke or check:
        check_against_committed(grid)
    if json_path:
        write_json(json_path)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized settings + determinism/staleness checks")
    ap.add_argument("--check", action="store_true",
                    help="regenerate the grid and fail if the committed "
                         "BENCH_paged_arena.json is stale")
    ap.add_argument("--write", action="store_true",
                    help="refresh the committed BENCH_paged_arena.json")
    ap.add_argument("--json", default="")
    args = ap.parse_args(argv)
    run(smoke=args.smoke or args.write, write=args.write, check=args.check,
        json_path=args.json)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
