"""Event-driven simulator for disaggregated serving.

Two scenarios (Sec. 7.2):
  - "pd":   prefill cluster -> [compress -> transfer -> decompress] -> decode
            cluster; metric = JCT.
  - "pool": decode node fetches reusable KV from a remote pool (prefix
            caching) or recomputes prefill locally; metric = TTFT.

Fault model (large-scale runnability): persistent stragglers (per-node speed
factors), transient slowdowns, node failures with re-queue + retry, and
hedged pool fetches (duplicate read to a replica when the first read
exceeds its deadline estimate).

The policy object decides the compression profile per request from the
*estimated* goodput (EWMA over observed transfers), reproducing the
offline→online drift the residual bandit corrects.

Replay invariant: a run is a pure function of (config, seed) — no wall
clock, no global RNG state, no identity-based ordering.  The
``determinism`` static rule (DESIGN.md §13) enforces this mechanically
over this module, ``network.py`` and ``workloads/``.
"""
from __future__ import annotations

import gc
import heapq
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.controller import Decision, ServiceAwareController, ServiceContext
from repro.controller.latency_model import (
    expected_tokens_per_step,
    predicted_latency,
)
from repro.core.profiles import IDENTITY_PROFILE, Profile
from repro.core.strategy import paged_eligible
from repro.serving.kvstore import PrefixKVStore, StoreEntry, TieredKVStore
from repro.serving.network import (
    BandwidthTrace,
    GoodputEstimator,
    seed_bandwidth,
)
from repro.serving.request import Request
from repro.serving.scheduler import ContinuousScheduler, SchedulerConfig
from repro.serving.topology import NetworkTopology


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------
class Policy:
    name = "base"
    # Whether ``choose``/``feedback`` read the ServiceContext.  Policies
    # that ignore it (fixed-profile baselines) set this False so the hot
    # path can skip building a context per request — at a million requests
    # the allocation alone dominates the simulated cluster.  ``choose``
    # then receives ``ctx=None``.
    needs_ctx = True

    def choose(self, req: Request, ctx: Optional[ServiceContext]
               ) -> Tuple[Profile, Optional[Decision]]:
        raise NotImplementedError

    def feedback(self, ctx: Optional[ServiceContext],
                 decision: Optional[Decision], observed: float) -> None:
        pass


class NoCompressionPolicy(Policy):
    name = "default"
    needs_ctx = False

    def choose(self, req, ctx):
        return IDENTITY_PROFILE, None


class StaticPolicy(Policy):
    """A fixed profile regardless of service state (CacheGen/KIVI/Duo...)."""

    def __init__(self, profile: Profile, name: str,
                 slo_fallback_recompute: bool = False):
        self.profile = profile
        self.name = name
        # CacheGen's behaviour in Fig. 14: fall back to recomputation when
        # it cannot meet the target SLO.  Only that fallback reads the
        # service context (predicted_latency needs B and V).
        self.slo_fallback_recompute = slo_fallback_recompute
        self.needs_ctx = slo_fallback_recompute

    def choose(self, req, ctx):
        return self.profile, None


class KVServePolicy(Policy):
    name = "kvserve"

    def __init__(self, controller: ServiceAwareController):
        self.controller = controller

    def choose(self, req, ctx):
        decision = self.controller.select(ctx)
        return decision.profile, decision

    def feedback(self, ctx, decision, observed):
        if decision is not None:
            self.controller.observe(ctx, decision, observed)


# ---------------------------------------------------------------------------
# Cluster / fault model
# ---------------------------------------------------------------------------
@dataclass
class NodePool:
    """Idle-node tracker with O(log n) acquire/release.

    ``node_free`` is authoritative: per-node free time, ``None`` while the
    node is acquired.  ``heap`` carries (free_time, nid) reservations with
    LAZY deletion — an entry is valid only while it still matches
    ``node_free[nid]``; stale entries (from ``acquire_node`` pulls or
    superseded releases) are skipped on pop.  The previous implementation
    re-``heapify``-ed the whole heap on every routed acquire, which was
    the simulator's top hot spot on million-request traces.
    """

    n: int
    speed: List[float]          # persistent per-node speed factor
    node_free: List[Optional[float]] = field(default_factory=list)
    heap: List[Tuple[float, int]] = field(default_factory=list)

    @staticmethod
    def make(n: int, straggler_sigma: float, rng: np.random.Generator
             ) -> "NodePool":
        speed = np.exp(rng.normal(0.0, straggler_sigma, size=n))
        # Stragglers only slow down; plain floats keep every downstream
        # duration off numpy scalar arithmetic.
        speed = np.minimum(speed, 1.0).tolist()
        pool = NodePool(n=n, speed=speed)
        pool.node_free = [0.0] * n
        pool.heap = [(0.0, i) for i in range(n)]  # already heap-ordered
        return pool

    def acquire(self, now: float) -> Tuple[float, int]:
        """Earliest-free node: pops (skipping stale entries) and marks it
        acquired.  Ties break by node id, matching the old heap order."""
        heap = self.heap
        node_free = self.node_free
        while True:
            free, nid = heapq.heappop(heap)
            if node_free[nid] == free:
                node_free[nid] = None
                return (free if free > now else now), nid

    def acquire_node(self, nid: int, now: float) -> float:
        """Reserve a SPECIFIC node (the topology-routed decode target):
        returns its start time (>= now, after the node frees up)."""
        free = self.node_free[nid]
        if free is None:
            raise KeyError(f"node {nid} is not idle-tracked")
        self.node_free[nid] = None  # its heap entry goes stale in place
        return free if free > now else now

    def free_times(self) -> Dict[int, float]:
        """Current per-node free times (the router's decode queue view)."""
        return {nid: free for nid, free in enumerate(self.node_free)
                if free is not None}

    def next_free(self) -> Optional[float]:
        """Earliest free time among idle-tracked nodes (dispatch clock)."""
        best: Optional[float] = None
        for free in self.node_free:
            if free is not None and (best is None or free < best):
                best = free
        return best

    def release(self, nid: int, until: float) -> None:
        self.node_free[nid] = until
        heap = self.heap
        heapq.heappush(heap, (until, nid))
        if len(heap) > 2 * self.n + 32:
            # Routed (acquire_node) traffic never pops, so stale entries
            # accumulate; compact before the heap outgrows the pool.
            live = [(free, nid) for nid, free in enumerate(self.node_free)
                    if free is not None]
            heapq.heapify(live)
            self.heap = live


@dataclass
class SimConfig:
    scenario: str = "pd"            # pd | pool
    n_prefill: int = 4
    n_decode: int = 2
    prefill_tok_s: float = 20000.0  # tokens/s per prefill node
    decode_tok_s: float = 120.0     # tokens/s per decode node
    straggler_sigma: float = 0.0
    transient_slow_p: float = 0.0   # per-task transient slowdown prob
    transient_slow_factor: float = 3.0
    fail_rate: float = 0.0          # failures per node-second of busy time
    max_retries: int = 2
    hedge_factor: float = 0.0       # >0: hedged pool fetch at factor×estimate
    pool_fetch_overhead: float = 0.002
    estimator_alpha: float = 0.3
    # Decode side runs the paged arena with fused dequant-attention
    # (DESIGN.md §12): paged-eligible profiles skip the materialized
    # decompress, so their V/s_dec term leaves the critical path.
    paged: bool = False
    # Speculative decode on the decode fleet (DESIGN.md §15): spec_k > 0
    # divides each request's decode time by its committed-tokens-per-
    # verify-step, derived from spec_accept via the controller's
    # geometric model.  Per-request acceptance is a pure hash of
    # (seed, rid) — no rng state is consumed, so replays stay a pure
    # function of (config, seed) and spec_k = 0 is bit-identical to
    # runs that predate the field.
    spec_k: int = 0
    spec_accept: float = 0.0
    seed: int = 0


def spec_tokens_per_step(cfg: SimConfig, rid: int) -> float:
    """Committed tokens per verify step for request ``rid`` under
    ``cfg``'s speculation settings — the simulator's acceptance model.

    The per-request accept rate is ``cfg.spec_accept`` jittered by a
    Weyl-style integer hash of (seed, rid): requests repeat themselves
    to different degrees, but which ones do must not depend on run
    order, so the jitter is a pure function of the request identity and
    consumes NO rng state (the replay invariant in the module
    docstring).  The accept rate then feeds the controller's own
    geometric model (:func:`expected_tokens_per_step`), so what the
    simulator bills and what the controller predicts agree by
    construction.  ``spec_k <= 0`` returns exactly 1.0."""
    if cfg.spec_k <= 0:
        return 1.0
    u = ((rid * 2654435761 + cfg.seed * 97) % 1000) / 1000.0
    r = min(max(cfg.spec_accept + 0.1 * (u - 0.5), 0.0), 1.0)
    return expected_tokens_per_step(cfg.spec_k, r)


@dataclass
class SimResult:
    requests: List[Request]
    policy: str

    def completed(self) -> List[Request]:
        return [r for r in self.requests if r.chosen != "rejected"]

    def rejected(self) -> List[Request]:
        """Requests shed by admission control (scheduled dispatch only)."""
        return [r for r in self.requests if r.chosen == "rejected"]

    def jct(self) -> np.ndarray:
        return np.asarray([r.jct for r in self.completed()])

    def ttft(self) -> np.ndarray:
        return np.asarray([r.ttft for r in self.completed()])

    def mean_jct(self) -> float:
        """0.0 when nothing completed (never NaN, never a crash)."""
        vals = self.jct()
        return float(vals.mean()) if vals.size else 0.0

    def p95_jct(self) -> float:
        vals = self.jct()
        return float(np.percentile(vals, 95)) if vals.size else 0.0

    def mean_ttft(self) -> float:
        vals = self.ttft()
        return float(vals.mean()) if vals.size else 0.0

    def slo_attainment(self) -> float:
        with_slo = [r for r in self.requests if r.t_slo > 0]
        if not with_slo:
            return 1.0
        return float(np.mean([not r.slo_violated for r in with_slo]))

    def breakdown(self) -> Dict[str, float]:
        keys = ("prefill", "compress", "comm", "decompress", "decode",
                "queue", "retry")
        out = {k: 0.0 for k in keys}
        for r in self.requests:
            for k in keys:
                out[k] += r.breakdown.get(k, 0.0)
        n = max(len(self.requests), 1)
        return {k: v / n for k, v in out.items()}

    def summary(self) -> Dict[str, float]:
        """Distribution summary of the run: means, p50/p95/p99 TTFT and
        JCT tails, and per-SLO-class violation rates — the same metric
        block the real-execution runtimes emit, so simulator sweeps are
        directly comparable with engine runs."""
        from repro.serving.metrics import latency_summary, route_counts
        done = self.completed()
        out: Dict[str, float] = {
            "completed": float(len(done)),
            "rejected": float(len(self.rejected())),
            "slo_attainment": self.slo_attainment(),
        }
        if done:
            out["mean_jct"] = self.mean_jct()
            out["mean_ttft"] = self.mean_ttft()
            makespan = max(r.done for r in done)
            out["throughput_rps"] = (len(done) / makespan
                                     if makespan > 0 else 0.0)
        # Per-class blocks cover every class SUBMITTED (not just the
        # completed ones): a class whose requests were all shed still
        # appears — completed 0, percentiles None, violation rate 0.
        classes = sorted({r.slo_class for r in self.requests})
        out.update(latency_summary(done, classes=classes))
        out.update(route_counts(done))
        return out


def _sim_recompress(entry: StoreEntry, profile: Profile
                    ) -> Optional[Tuple[Profile, int]]:
    """Byte-accounting demotion re-compression for simulator payloads
    (the stored payload IS the profile it was compressed with)."""
    if entry.kv_bytes <= 0:
        return None
    wire = int(entry.kv_bytes / max(profile.cr, 1.0))
    if wire >= entry.wire_bytes:
        return None
    return profile, wire


class Simulator:
    """Event-driven serving simulator.

    Optional serving-runtime integrations (shared with the real-execution
    engine, see DESIGN.md §9):

    * ``store`` — a :class:`PrefixKVStore` (flat pool) or a
      :class:`TieredKVStore` (HBM/DRAM/remote hierarchy); the pool
      scenario then resolves hits/misses (and capacity eviction /
      demotion / promotion) through the store via each request's
      ``prefix_key`` instead of the static ``prefix_hit`` flag.  With a
      tiered store, fetches and write-backs are routed through the
      holding tier's serialized link, so concurrent pool traffic
      contends.  ``hedge_factor`` hedges slow fetches on the flat path
      and on the tiered store's REMOTE tier (the replicated pool);
      local HBM/DRAM tiers are never hedged — there is no replica of a
      worker's own memory to race.
    * ``scheduler`` — a :class:`SchedulerConfig`; requests are then
      dispatched through :class:`ContinuousScheduler` (admission control +
      SLO-class priority order) rather than strict arrival order.
    * ``topology`` + ``routing`` — a
      :class:`~repro.serving.topology.NetworkTopology` of per-(prefill
      node, decode node) serialized links; the pd scenario then routes
      every transfer over its pair's own wire ("round_robin" baseline or
      "load_aware" predicted-latency argmin), which is the event-driven
      twin of :class:`~repro.serving.cluster.ClusterRuntime` for
      large-scale sweeps.
    """

    def __init__(self, config: SimConfig, policy: Policy,
                 trace: BandwidthTrace, requests: Sequence[Request],
                 store: Optional[object] = None,
                 scheduler: Optional[SchedulerConfig] = None,
                 topology: Optional[NetworkTopology] = None,
                 routing: str = "load_aware"):
        self.cfg = config
        self.policy = policy
        self.trace = trace
        self.requests = list(requests)
        self.store = store
        self.scheduler_cfg = scheduler
        # Per-(prefill node, decode node) link topology (ISSUE 5): the pd
        # scenario then routes every transfer over the pair's own
        # serialized KVWire — the same NetworkTopology object the
        # real-execution ClusterRuntime drives, at event-driven scale.
        self.topology = topology
        if routing not in ("load_aware", "round_robin"):
            # validated with or without a topology: a typo'd policy name
            # should fail at construction, not when a topology is later
            # added to the sweep
            raise ValueError(f"unknown routing policy {routing!r}")
        if topology is not None:
            if (topology.n_prefill != config.n_prefill
                    or topology.n_decode != config.n_decode):
                raise ValueError(
                    f"topology is {topology.n_prefill}x{topology.n_decode} "
                    f"but the cluster has {config.n_prefill} prefill x "
                    f"{config.n_decode} decode nodes")
        self.routing = routing
        self._rr_next = 0
        self.rng = np.random.default_rng(config.seed)
        # Hot-path caches: profile -> display name (short_name() rebuilds
        # its string per call), the scenario's default SLO metric, and
        # whether the pool path needs the CacheGen-style SLO fallback.
        self._names: Dict[int, Tuple[Profile, str]] = {}
        self._default_metric = "jct" if config.scenario == "pd" else "ttft"
        self._static_fallback = (isinstance(policy, StaticPolicy)
                                 and policy.slo_fallback_recompute)
        self.estimator = GoodputEstimator(alpha=config.estimator_alpha,
                                          initial=seed_bandwidth(trace))
        self._tiered = isinstance(store, TieredKVStore)
        if self._tiered:
            if store.estimator is None:
                store.estimator = self.estimator
            if store.recompress is None:
                store.recompress = _sim_recompress
        self.prefill = NodePool.make(config.n_prefill,
                                     config.straggler_sigma, self.rng)
        self.decode = NodePool.make(config.n_decode, config.straggler_sigma,
                                    self.rng)

    # ------------------------------------------------------------------
    def _task_time(self, base: float, pool: NodePool, nid: int) -> float:
        t = base / pool.speed[nid]
        if self.cfg.transient_slow_p > 0 and \
                self.rng.random() < self.cfg.transient_slow_p:
            t *= self.cfg.transient_slow_factor
        return t

    def _maybe_fail(self, duration: float) -> Optional[float]:
        """Returns time-until-failure if the node dies mid-task."""
        if self.cfg.fail_rate <= 0:
            return None
        u = self.rng.random()
        p_fail = 1.0 - math.exp(-self.cfg.fail_rate * duration)
        if u < p_fail:
            return float(self.rng.uniform(0.1, 0.9)) * duration
        return None

    def _run_on_pool(self, pool: NodePool, now: float, base_time: float,
                     req: Request) -> Tuple[float, float, int]:
        """Execute a compute task with failure/straggler handling.
        Returns (finish_time, queue_wait, node_id)."""
        attempts = 0
        t = now
        queue_wait = 0.0
        while True:
            start, nid = pool.acquire(t)
            queue_wait += start - t
            dur = self._task_time(base_time, pool, nid)
            fail_at = self._maybe_fail(dur) if attempts < self.cfg.max_retries \
                else None
            if fail_at is None:
                pool.release(nid, start + dur)
                return start + dur, queue_wait, nid
            # node died mid-task: lose partial work, re-queue elsewhere
            pool.release(nid, start + fail_at + 1.0)  # node recovers later
            req.retries += 1
            req.breakdown["retry"] = req.breakdown.get("retry", 0.0) + fail_at
            attempts += 1
            t = start + fail_at

    def _run_on_node(self, pool: NodePool, nid: int, now: float,
                     base_time: float, req: Request) -> Tuple[float, float]:
        """Execute on a SPECIFIC node (topology-routed placement): the
        full straggler/transient/failure model applies, but a mid-task
        failure RETRIES ON THE PINNED NODE after it recovers instead of
        re-routing (the route decided the placement).  Returns
        (finish_time, queue_wait)."""
        start = pool.acquire_node(nid, now)
        queue_wait = start - now
        t = start
        attempts = 0
        while True:
            dur = self._task_time(base_time, pool, nid)
            fail_at = self._maybe_fail(dur) \
                if attempts < self.cfg.max_retries else None
            if fail_at is None:
                pool.release(nid, t + dur)
                return t + dur, queue_wait
            req.retries += 1
            req.breakdown["retry"] = req.breakdown.get("retry", 0.0) + fail_at
            attempts += 1
            t = t + fail_at + 1.0     # pinned node recovers, then retry

    # ------------------------------------------------------------------
    def run(self) -> SimResult:
        # The replay loop allocates millions of small ACYCLIC objects
        # (per-request breakdown dicts, heap tuples) that all stay
        # reachable from self.requests, so generational GC finds nothing
        # yet rescans the growing heap over and over — ~4x the entire
        # replay cost at a million requests.  Defer collection for the
        # duration; re-enable (and let the caller's thresholds catch up)
        # on the way out.
        was_enabled = gc.isenabled()
        if was_enabled:
            gc.disable()
        try:
            if self.scheduler_cfg is not None:
                self._run_scheduled()
                return SimResult(self.requests, self.policy.name)
            if self.cfg.scenario == "pd":
                if self._fast_pd_eligible():
                    self._run_fast_pd()
                else:
                    for req in self.requests:
                        self._run_pd(req)
            else:
                for req in self.requests:
                    self._run_pool(req)
            return SimResult(self.requests, self.policy.name)
        finally:
            if was_enabled:
                gc.enable()

    # ------------------------------------------------------------------
    # Bulk pd replay (the million-request hot path)
    # ------------------------------------------------------------------
    def _fast_pd_eligible(self) -> bool:
        """The inlined pd loop applies when per-request dispatch has no
        data-dependent branching to honor: no per-link topology, no fault
        injection (those draw from the rng mid-request), and a
        fixed-profile policy that ignores the service context.  Every
        other configuration takes the general per-request path."""
        cfg = self.cfg
        policy = self.policy
        return (self.topology is None
                and cfg.fail_rate <= 0
                and cfg.transient_slow_p <= 0
                and not policy.needs_ctx
                and type(policy).choose in (StaticPolicy.choose,
                                            NoCompressionPolicy.choose)
                and type(policy).feedback is Policy.feedback)

    def _run_fast_pd(self) -> None:
        """Inlined twin of :meth:`_run_pd` for the eligible configuration.
        Every float expression mirrors the general path op-for-op, so the
        two produce bit-identical requests (the sim_speed benchmark
        asserts it); what is removed is per-request call and allocation
        overhead — ServiceContext construction, pool/transfer/estimator
        method dispatch, dict re-writes — which dominated replay time on
        million-request traces."""
        requests = self.requests
        if not requests:
            return
        cfg = self.cfg
        profile, _ = self.policy.choose(requests[0], None)
        name = self._profile_name(profile)
        pre_tok = cfg.prefill_tok_s
        dec_tok = cfg.decode_tok_s
        s_enc, s_dec, cr = profile.s_enc, profile.s_dec, profile.cr
        enc_inf = s_enc == float("inf")
        # fixed profile -> the fused-dequant gate is loop-invariant
        dec_inf = (s_dec == float("inf")
                   or (cfg.paged and paged_eligible(profile.strategy)))
        trace = self.trace
        const = (trace.jitter <= 0 and len(trace.times) == 1
                 and trace.values[0] > 0.0)
        rate = trace.values[0] if const else 0.0
        est = self.estimator
        alpha = est.alpha
        one_m_alpha = 1 - alpha
        e = est._est
        prefill, decode = self.prefill, self.decode
        pheap, dheap = prefill.heap, decode.heap
        pspeed, dspeed = prefill.speed, decode.speed
        heappush, heappop = heapq.heappush, heapq.heappop
        isfinite = math.isfinite
        default_metric = self._default_metric
        spec_on = cfg.spec_k > 0

        for req in requests:
            arrival = req.arrival
            # prefill on the earliest-free node (no stale heap entries
            # without acquire_node traffic)
            free, nid = heappop(pheap)
            s0 = free if free > arrival else arrival
            t = s0 + (req.ctx_tokens / pre_tok) / pspeed[nid]
            heappush(pheap, (t, nid))
            q_wait = s0 - arrival

            # compress -> transfer -> decompress
            v = req.kv_bytes
            t_c = 0.0 if enc_inf else v / s_enc
            payload = v / cr
            t_comm = payload / rate if const \
                else trace.transfer_time(t + t_c, payload)
            if t_comm > 0 and payload > 0 and isfinite(t_comm):
                goodput = payload / t_comm
                e = goodput if e is None \
                    else one_m_alpha * e + alpha * goodput
            t_d = 0.0 if dec_inf else v / s_dec
            bd_prefill = t - arrival - q_wait - 0.0
            t = t + t_c + t_comm + t_d
            ttft = t - arrival
            req.ttft = ttft

            # decode on the earliest-free node (same two-step arithmetic
            # as _run_pd: divide-then-divide, never a fused expression,
            # so the floats match bit-for-bit)
            t_dec_base = req.out_tokens / dec_tok
            if spec_on:
                t_dec_base /= spec_tokens_per_step(cfg, req.rid)
            free2, nid2 = heappop(dheap)
            s1 = free2 if free2 > t else t
            t_end = s1 + t_dec_base / dspeed[nid2]
            heappush(dheap, (t_end, nid2))

            # mirror the general path op-for-op: q_wait2 accumulates from
            # 0.0, decode is ACTUAL elapsed minus queue (straggler-aware),
            # retry delta is identically 0.0 here (no faults when eligible)
            q2 = 0.0 + (s1 - t)
            req.breakdown = {
                "prefill": bd_prefill,
                "queue": (q_wait + 0.0) + q2,
                "compress": t_c, "comm": t_comm, "decompress": t_d,
                "decode": t_end - t - q2 - 0.0,
            }
            req.done = t_end
            req.chosen = name
            metric = req.slo_metric
            if metric is None:
                metric = default_metric
            observed = ttft if metric == "ttft" else t_end - arrival
            t_slo = req.t_slo
            req.slo_violated = t_slo > 0 and observed > t_slo

        est._est = e
        for free, nid in pheap:
            prefill.node_free[nid] = free
        for free, nid in dheap:
            decode.node_free[nid] = free

    def _run_scheduled(self) -> None:
        """Dispatch through the shared ContinuousScheduler: admission
        control sheds load beyond the queue bound, and waiting requests are
        served in priority (not arrival) order.  The dispatch clock advances
        to the next prefill-node free time, so under overload a backlog
        accumulates and SLO-class ordering becomes visible."""
        sched = ContinuousScheduler(self.scheduler_cfg)
        pending = sorted(self.requests, key=lambda r: r.arrival)
        idx, n = 0, len(pending)
        now = 0.0
        while idx < n or sched.queue_depth:
            while idx < n and pending[idx].arrival <= now:
                sched.submit(pending[idx], now)
                idx += 1
            if sched.queue_depth == 0:
                if idx >= n:   # everything left was shed by admission
                    break
                now = pending[idx].arrival
                continue
            req = sched.pop_next(now)
            start = max(now, req.arrival)
            if self.cfg.scenario == "pd":
                self._run_pd(req, start)
            else:
                self._run_pool(req, start)
            nxt = self.prefill.next_free()
            if nxt is not None:
                now = max(now, nxt)

    # ------------------------------------------------------------------
    def _slo_metric(self, req: Request) -> str:
        """Scenario default (pd -> jct, pool -> ttft) unless the request
        pins one — the same resolution rule as the real runtime."""
        m = req.slo_metric
        return m if m is not None else self._default_metric

    def _profile_name(self, profile: Profile) -> str:
        # Keyed by id with the profile pinned in the entry, so a recycled
        # id (GC'd temporary) can never alias onto a stale name.
        hit = self._names.get(id(profile))
        if hit is not None and hit[0] is profile:
            return hit[1]
        name = profile.strategy.short_name()
        self._names[id(profile)] = (profile, name)
        return name

    def _service_context(self, req: Request, t_model: float) -> ServiceContext:
        return ServiceContext(
            workload=req.workload, bandwidth=self.estimator.estimate,
            t_slo=req.t_slo, q_min=req.q_min, t_model=t_model,
            kv_bytes=req.kv_bytes, slo_metric=self._slo_metric(req),
            fused_dec=self.cfg.paged)

    def _decompress_time(self, profile: Profile, v: float) -> float:
        """V/s_dec — except under the paged arena (``cfg.paged``), where a
        paged-eligible profile's pages feed the fused dequant-attention
        kernel directly and the materialized decompress vanishes."""
        if self.cfg.paged and paged_eligible(profile.strategy):
            return 0.0
        return 0.0 if profile.s_dec == float("inf") else v / profile.s_dec

    def _transfer(self, start: float, nbytes: float) -> float:
        dt = self.trace.transfer_time(start, nbytes)
        self.estimator.observe(nbytes, dt)
        return dt

    # ------------------------------------------------------------------
    def _choose_decode(self, src: int, ready: float, payload_hint: float
                       ) -> int:
        """Pick the decode node for a transfer leaving prefill node
        ``src``: round-robin cycles the decode nodes; load-aware takes the
        argmin of (link reservation backlog + estimated transfer at the
        link's own goodput estimate + decode node busy time) — predicted
        completion over live queue depths, per-link estimators included.
        """
        topo = self.topology
        if self.routing == "round_robin":
            d = self._rr_next % topo.n_decode
            self._rr_next += 1
            return d
        free = self.decode.free_times()

        def cost(d: int) -> float:
            link = topo.link(src, d)
            t_link = (max(link.free_at - ready, 0.0)
                      + payload_hint / max(link.estimator.estimate, 1e-9))
            return t_link + max(free.get(d, 0.0) - ready, 0.0)

        return min(range(topo.n_decode), key=lambda d: (cost(d), d))

    def _run_pd(self, req: Request, start: Optional[float] = None) -> None:
        if self.topology is not None:
            return self._run_pd_topology(req, start)
        cfg = self.cfg
        start = req.arrival if start is None else start
        t_prefill_base = req.ctx_tokens / cfg.prefill_tok_s
        t_decode_base = req.out_tokens / cfg.decode_tok_s
        if cfg.spec_k > 0:
            t_decode_base /= spec_tokens_per_step(cfg, req.rid)
        ctx = self._service_context(req, t_prefill_base + t_decode_base) \
            if self.policy.needs_ctx else None
        profile, decision = self.policy.choose(req, ctx)
        req.chosen = self._profile_name(profile)

        # prefill
        t, q_wait, pid = self._run_on_pool(self.prefill, start,
                                           t_prefill_base, req)
        req.breakdown["prefill"] = t - start - q_wait \
            - req.breakdown.get("retry", 0.0)
        req.breakdown["queue"] = q_wait + (start - req.arrival)

        # compress -> transfer -> decompress
        v = req.kv_bytes
        t_c = 0.0 if profile.s_enc == float("inf") else v / profile.s_enc
        payload = v / profile.cr
        t_comm = self._transfer(t + t_c, payload)
        t_d = self._decompress_time(profile, v)
        req.breakdown["compress"] = t_c
        req.breakdown["comm"] = t_comm
        req.breakdown["decompress"] = t_d
        t = t + t_c + t_comm + t_d
        req.ttft = t - req.arrival  # first decode token comes right after

        # decode — billed at ACTUAL elapsed time (straggler/transient
        # slowdowns included), not the base estimate, so the breakdown
        # terms always sum to JCT
        retry0 = req.breakdown.get("retry", 0.0)
        t_dec = t
        t, q_wait2, _ = self._run_on_pool(self.decode, t, t_decode_base, req)
        req.breakdown["decode"] = t - t_dec - q_wait2 \
            - (req.breakdown.get("retry", 0.0) - retry0)
        req.breakdown["queue"] += q_wait2
        req.done = t
        # Metric-matched feedback (same rule as the runtime's _finish):
        # the bandit's violation cooldown fires on the latency reported as
        # slo_violated, never a different quantity.
        metric = self._slo_metric(req)
        observed = req.ttft if metric == "ttft" else req.jct
        req.slo_violated = req.t_slo > 0 and observed > req.t_slo
        self.policy.feedback(ctx, decision, observed)

    def _run_pd_topology(self, req: Request,
                         start: Optional[float] = None) -> None:
        """PD over the per-link topology: prefill on whichever node frees
        first (node ``src``), route the transfer to a decode node
        (round-robin or load-aware), bill it on the (src, dst) pair's OWN
        serialized :class:`~repro.serving.network.KVWire` (concurrent
        transfers on the same link queue — ``wire_wait``; different links
        overlap), then decode pinned on ``dst``.  The profile decision is
        made AFTER the route is known, from the route's per-link goodput
        estimate, and the context carries the route id so the residual
        bandit learns each link's drift separately."""
        from repro.serving.topology import route_name
        cfg = self.cfg
        start = req.arrival if start is None else start
        t_prefill_base = req.ctx_tokens / cfg.prefill_tok_s
        t_decode_base = req.out_tokens / cfg.decode_tok_s
        if cfg.spec_k > 0:
            t_decode_base /= spec_tokens_per_step(cfg, req.rid)

        # prefill
        t, q_wait, src = self._run_on_pool(self.prefill, start,
                                           t_prefill_base, req)
        req.breakdown["prefill"] = t - start - q_wait \
            - req.breakdown.get("retry", 0.0)
        req.breakdown["queue"] = q_wait + (start - req.arrival)

        # route + profile decision at the route's own bandwidth view
        dst = self._choose_decode(src, t, req.kv_bytes)
        link = self.topology.link(src, dst)
        req.route = route_name(src, dst)
        ctx = None
        if self.policy.needs_ctx:
            ctx = ServiceContext(
                workload=req.workload, bandwidth=link.estimator.estimate,
                t_slo=req.t_slo, q_min=req.q_min,
                t_model=t_prefill_base + t_decode_base,
                kv_bytes=req.kv_bytes,
                slo_metric=self._slo_metric(req), route=req.route)
        profile, decision = self.policy.choose(req, ctx)
        req.chosen = self._profile_name(profile)

        # compress -> per-link serialized transfer -> decompress
        v = req.kv_bytes
        t_c = 0.0 if profile.s_enc == float("inf") else v / profile.s_enc
        payload = v / profile.cr
        tr = link.send(t + t_c, payload)
        t_d = self._decompress_time(profile, v)
        req.breakdown["compress"] = t_c
        req.breakdown["wire_wait"] = tr.t_wait
        req.breakdown["comm"] = tr.t_comm
        req.breakdown["decompress"] = t_d
        t = t + t_c + tr.t_wait + tr.t_comm + t_d
        req.ttft = t - req.arrival  # first decode token comes right after

        # decode, pinned on the routed node — billed at ACTUAL elapsed
        # time (stragglers/retries included) so breakdowns sum to JCT
        retry0 = req.breakdown.get("retry", 0.0)
        t_end, q_wait2 = self._run_on_node(self.decode, dst, t,
                                           t_decode_base, req)
        req.breakdown["decode"] = t_end - t - q_wait2 \
            - (req.breakdown.get("retry", 0.0) - retry0)
        req.breakdown["queue"] += q_wait2
        req.done = t_end
        metric = self._slo_metric(req)
        observed = req.ttft if metric == "ttft" else req.jct
        req.slo_violated = req.t_slo > 0 and observed > req.t_slo
        self.policy.feedback(ctx, decision, observed)

    # ------------------------------------------------------------------
    def _run_pool(self, req: Request, start: Optional[float] = None) -> None:
        """Prefix-caching: fetch compressed KV from the remote pool or
        recompute prefill.  TTFT is the metric.

        With a :class:`PrefixKVStore` attached, hits/misses come from real
        store state (prefix matching + capacity eviction): a miss recomputes
        and writes the compressed KV back (off the critical path), a hit
        fetches the *stored* entry's bytes.  Without a store, the request's
        static ``prefix_hit`` flag decides, and the fetch is billed at the
        policy-chosen profile."""
        cfg = self.cfg
        start = req.arrival if start is None else start
        sched_wait = start - req.arrival
        t_prefill_base = req.ctx_tokens / cfg.prefill_tok_s
        ctx = self._service_context(req, cfg.pool_fetch_overhead) \
            if self.policy.needs_ctx else None
        profile, decision = self.policy.choose(req, ctx)
        req.chosen = self._profile_name(profile)

        entry = None
        hit = None      # TierHit when the store is a TieredKVStore
        tiered = self._tiered
        if self.store is not None:
            key = req.prefix_key if req.prefix_key is not None else (req.rid,)
            if tiered:
                hit = self.store.lookup(key, now=start)
                entry = hit.entry if hit is not None else None
            else:
                entry = self.store.lookup(key, now=start)
            recompute = entry is None
        else:
            recompute = not req.prefix_hit
        if not recompute and self._static_fallback and req.t_slo > 0:
            # CacheGen-style: if the static profile cannot meet SLO, degrade
            # to full recomputation (Fig. 14).
            pred = predicted_latency(profile, ctx)
            if pred > req.t_slo:
                recompute = True

        if recompute:
            t, q_wait, _ = self._run_on_pool(self.prefill, start,
                                             t_prefill_base, req)
            req.breakdown["prefill"] = t - start - q_wait \
                - req.breakdown.get("retry", 0.0)
            req.breakdown["queue"] = q_wait + sched_wait
            req.ttft = t - req.arrival
            req.done = t
            req.slo_violated = req.t_slo > 0 and req.ttft > req.t_slo
            if self.store is not None:
                # Write the freshly compressed prefix back to the pool (off
                # the critical path).  The entry is stamped with the write's
                # *completion* time (compress + wire) so lookups can't hit
                # bytes still in flight — same rule as the engine path.
                payload = req.kv_bytes / profile.cr
                t_c = 0.0 if profile.s_enc == float("inf") \
                    else req.kv_bytes / profile.s_enc
                if tiered:
                    # Routed through the hot tier's serialized link:
                    # write-backs contend with concurrent fetches.
                    self.store.write(key, profile, int(payload),
                                     kv_bytes=req.kv_bytes,
                                     workload=req.workload,
                                     slo_class=req.slo_class,
                                     ready=t + t_c, tier=0)
                else:
                    t_w = self._transfer(t + t_c, payload)
                    self.store.put(key, profile, int(payload),
                                   kv_bytes=req.kv_bytes,
                                   workload=req.workload,
                                   slo_class=req.slo_class,
                                   now=t + t_c + t_w)
            self.policy.feedback(ctx, decision, req.ttft)
            return

        # fetch compressed KV from the pool (with optional hedging)
        if entry is not None:
            # Physically coherent: the wire carries what the pool stored.
            stored: Profile = entry.payload
            v = entry.kv_bytes
            payload = float(entry.wire_bytes)
            t_d = self._decompress_time(stored, v)
            req.chosen = self._profile_name(stored)
        else:
            v = req.kv_bytes
            payload = v / profile.cr
            t_d = self._decompress_time(profile, v)
        if hit is not None:
            # Tiered fetch: the holding tier's serialized link (concurrent
            # fetches queue — wire_wait is on the critical path); the
            # fetched entry promotes to the hot tier.  Hedging models a
            # replicated pool, so it applies to the REMOTE tier only (the
            # shared pool has replicas; a worker's own HBM/DRAM does not):
            # the duplicate fetch races on the replica's own wire, not the
            # primary's serialized queue.
            overhead = hit.tier.fetch_overhead
            tr = self.store.fetch(hit, ready=start)
            t_comm = tr.t_comm
            if cfg.hedge_factor > 0 and hit.tier.spec.observe_goodput:
                expected = payload / self.estimator.estimate
                if t_comm > cfg.hedge_factor * expected:
                    t_comm2 = (hit.tier.fetch_overhead
                               + hit.tier.trace.transfer_time(
                                   start + cfg.hedge_factor * expected,
                                   payload))
                    t_comm = min(t_comm,
                                 cfg.hedge_factor * expected + t_comm2)
                    req.retries += 1
            req.breakdown["wire_wait"] = tr.t_wait
            fetch_start = overhead + tr.t_wait
        else:
            overhead = cfg.pool_fetch_overhead
            t0 = start + overhead
            t_comm = self._transfer(t0, payload)
            if cfg.hedge_factor > 0:
                expected = payload / self.estimator.estimate
                if t_comm > cfg.hedge_factor * expected:
                    # hedged duplicate fetch from a replica
                    t_comm2 = cfg.pool_fetch_overhead + self._transfer(
                        t0 + cfg.hedge_factor * expected, payload)
                    t_comm = min(t_comm,
                                 cfg.hedge_factor * expected + t_comm2)
                    req.retries += 1
            fetch_start = overhead
        req.breakdown["queue"] = sched_wait
        req.breakdown["comm"] = t_comm
        req.breakdown["decompress"] = t_d
        fetch_done = start + fetch_start + t_comm + t_d
        # Coverage of this request's prompt by the stored prefix: by token
        # count for real prefix keys, by KV bytes for synthetic (opaque)
        # keys where the writer's context may be shorter than ours.
        frac = 1.0
        if entry is not None:
            if req.prefix_key is not None \
                    and len(entry.tokens) < len(req.prefix_key):
                frac = len(entry.tokens) / len(req.prefix_key)
            elif entry.kv_bytes > 0 and req.kv_bytes > entry.kv_bytes:
                frac = entry.kv_bytes / req.kv_bytes
        if frac < 1.0:
            # Partial prefix hit: the uncovered prompt suffix still needs
            # a top-up prefill on the prefill pool.
            t_end, q_wait, _ = self._run_on_pool(
                self.prefill, fetch_done, (1.0 - frac) * t_prefill_base, req)
            req.breakdown["queue"] += q_wait
            req.breakdown["prefill"] = t_end - fetch_done - q_wait \
                - req.breakdown.get("retry", 0.0)
            req.ttft = t_end - req.arrival
        else:
            req.ttft = fetch_done - req.arrival
        req.done = req.arrival + req.ttft
        req.slo_violated = req.t_slo > 0 and req.ttft > req.t_slo
        if entry is None:
            # Feedback only when the policy's own choice was exercised —
            # store hits are served at the stored profile.
            self.policy.feedback(ctx, decision, req.ttft)
