"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    decode_attention_op,
    dequant_unpack_op,
    hadamard_op,
    quant_pack_op,
)
from repro.kernels import ops as K


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("t,d,group", [(256, 128, 64), (512, 64, 32),
                                       (128, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_pack_matches_ref(bits, t, d, group, dtype):
    rng = np.random.default_rng(bits * 1000 + t + d)
    x = jnp.asarray(rng.standard_normal((t, d)) * 4, dtype)
    codes, scales = quant_pack_op(x, bits=bits, group=group,
                                  block_tokens=min(128, t))
    cref, sref = K.quant_pack_ref(x.astype(jnp.float32), bits, group)
    got, want = np.asarray(codes), np.asarray(cref)
    if dtype == jnp.float32:
        np.testing.assert_array_equal(got, want)
    else:
        # bf16 inputs: interpret-mode vs jit'd ref may differ by one code at
        # exact rounding boundaries (<0.1% of elements)
        if bits == 4:  # compare unpacked nibbles, not packed bytes
            got = np.asarray(K.unpack_int4_ref(jnp.asarray(got)))
            want = np.asarray(K.unpack_int4_ref(jnp.asarray(want)))
        diff = got.astype(np.int32) - want.astype(np.int32)
        assert np.abs(diff).max() <= 1
        # coarser int4 grids hit .5 rounding boundaries more often
        assert (diff != 0).mean() < (1e-2 if bits == 4 else 1e-3)
    np.testing.assert_allclose(np.asarray(scales), np.asarray(sref),
                               rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_dequant_unpack_matches_ref(bits, out_dtype):
    rng = np.random.default_rng(bits)
    x = jnp.asarray(rng.standard_normal((128, 64)) * 3, jnp.float32)
    codes, scales = K.quant_pack_ref(x, bits, 32)
    got = dequant_unpack_op(codes, scales, bits=bits, group=32,
                            out_dtype=out_dtype)
    want = K.dequant_unpack_ref(codes, scales, bits, 32, dtype=out_dtype)
    assert got.dtype == want.dtype == out_dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-2 if out_dtype == jnp.bfloat16 else 1e-6,
                               atol=1e-6)


@pytest.mark.parametrize("bits", [4, 8])
def test_dequant_roundtrip_error_bound(bits):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((256, 128)) * 2, jnp.float32)
    codes, scales = quant_pack_op(x, bits=bits, group=64)
    xr = dequant_unpack_op(codes, scales, bits=bits, group=64,
                           out_dtype=jnp.float32)
    qmax = (1 << (bits - 1)) - 1
    # per-group symmetric: |err| <= scale = amax/qmax
    bound = float(jnp.abs(x).max()) / qmax + 1e-6
    assert float(jnp.abs(xr - x).max()) <= bound


@pytest.mark.parametrize("t,d", [(256, 64), (512, 128), (128, 256)])
def test_hadamard_matches_ref(t, d):
    rng = np.random.default_rng(t + d)
    x = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    y = hadamard_op(x, block_tokens=min(128, t))
    np.testing.assert_allclose(np.asarray(y), np.asarray(K.hadamard_ref(x)),
                               atol=1e-5)


def test_hadamard_involution():
    """H is orthonormal-symmetric: applying twice returns the input."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
    y = hadamard_op(hadamard_op(x))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-4)


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("b,hkv,gq,d,s,group,blk", [
    (2, 2, 4, 64, 512, 64, 128),
    (1, 4, 8, 128, 256, 32, 256),
    (3, 1, 2, 128, 1024, 128, 256),
])
def test_decode_attention_matches_ref(bits, b, hkv, gq, d, s, group, blk):
    rng = np.random.default_rng(bits + b + s)
    q = jnp.asarray(rng.standard_normal((b, hkv, gq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    kc8, ks = K.quantize_ref(k, bits, group)
    vc8, vs = K.quantize_ref(v, bits, group)
    kc = K.pack_int4_ref(kc8) if bits == 4 else kc8
    vc = K.pack_int4_ref(vc8) if bits == 4 else vc8
    kv_len = s - s // 4
    out = decode_attention_op(q, kc, ks, vc, vs, bits=bits, group=group,
                              kv_len=kv_len, block_s=blk)
    ref = K.decode_attention_ref(q, kc8, ks, vc8, vs, group, kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=1e-4)


@pytest.mark.parametrize("bits", [4, 8])
def test_decode_attention_multi_slot_matches_ref(bits):
    """Slot-arena decode: per-row ragged kv_lens vs the oracle, and vs
    row-by-row single-slot kernel calls."""
    rng = np.random.default_rng(31 + bits)
    b, hkv, gq, d, s, group, blk = 4, 2, 4, 64, 512, 64, 128
    q = jnp.asarray(rng.standard_normal((b, hkv, gq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    kc8, ks = K.quantize_ref(k, bits, group)
    vc8, vs = K.quantize_ref(v, bits, group)
    kc = K.pack_int4_ref(kc8) if bits == 4 else kc8
    vc = K.pack_int4_ref(vc8) if bits == 4 else vc8
    kv_lens = jnp.asarray([s, s // 2, 3, s - 17], jnp.int32)
    out = decode_attention_op(q, kc, ks, vc, vs, bits=bits, group=group,
                              kv_len=kv_lens, block_s=blk)
    ref = K.decode_attention_ref(q, kc8, ks, vc8, vs, group, kv_len=kv_lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=1e-4)
    # each row must equal a standalone single-slot call at its own length
    for i, n in enumerate(np.asarray(kv_lens)):
        one = decode_attention_op(q[i:i+1], kc[i:i+1], ks[i:i+1], vc[i:i+1],
                                  vs[i:i+1], bits=bits, group=group,
                                  kv_len=int(n), block_s=blk)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(one[0]),
                                   atol=2e-5, rtol=1e-4)


def test_decode_attention_quantized_close_to_exact():
    """int8 KV attention stays close to full-precision attention."""
    rng = np.random.default_rng(9)
    b, hkv, gq, d, s = 2, 2, 4, 64, 512
    q = jnp.asarray(rng.standard_normal((b, hkv, gq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    kc, ks = K.quantize_ref(k, 8, 64)
    vc, vs = K.quantize_ref(v, 8, 64)
    out = decode_attention_op(q, kc, ks, vc, vs, bits=8, group=64)
    # exact attention
    import math
    scores = jnp.einsum("bhgd,bhsd->bhgs", q, k) / math.sqrt(d)
    probs = jax_softmax = jnp.exp(scores - scores.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    exact = jnp.einsum("bhgs,bhsd->bhgd", probs, v)
    assert float(jnp.abs(out - exact).max()) < 0.05


def test_int4_pack_roundtrip_property():
    rng = np.random.default_rng(3)
    codes = jnp.asarray(rng.integers(-8, 8, size=(64, 128)), jnp.int8)
    packed = K.pack_int4_ref(codes)
    assert packed.shape == (64, 64)
    np.testing.assert_array_equal(np.asarray(K.unpack_int4_ref(packed)),
                                  np.asarray(codes))


# ---------------------------------------------------------------------------
# Property tests: ragged token counts (ISSUE 7 satellite).  quant_pack /
# dequant_unpack pad internally to the token-block grid, so token counts
# that are NOT multiples of block_tokens (or of anything) must round-trip
# exactly like their aligned counterparts.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("t", [1, 7, 100, 129, 300])
def test_quant_pack_ragged_token_counts(bits, t):
    d, group = 128, 64
    rng = np.random.default_rng(1000 + t + bits)
    x = jnp.asarray(rng.standard_normal((t, d)) * 3, jnp.float32)
    codes, scales = quant_pack_op(x, bits=bits, group=group)
    cref, sref = K.quantize_ref(x, bits, group)
    if bits == 4:
        cref = K.pack_int4_ref(cref)
    # padding rows must never perturb real rows: per-token quantization
    # is row-independent, so ragged == aligned, elementwise
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(cref))
    np.testing.assert_allclose(np.asarray(scales), np.asarray(sref),
                               rtol=1e-5, atol=1e-7)
    assert codes.shape[0] == t and scales.shape[0] == t


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("t", [5, 77, 200])
def test_dequant_ragged_roundtrip_bound(bits, t):
    d, group = 128, 32
    rng = np.random.default_rng(7 * t + bits)
    x = jnp.asarray(rng.standard_normal((t, d)) * 2, jnp.float32)
    codes, scales = quant_pack_op(x, bits=bits, group=group)
    xr = dequant_unpack_op(codes, scales, bits=bits, group=group,
                           out_dtype=jnp.float32)
    assert xr.shape == (t, d)
    qmax = (1 << (bits - 1)) - 1
    bound = float(jnp.abs(x).max()) / qmax + 1e-6
    assert float(jnp.abs(xr - x).max()) <= bound


# ---------------------------------------------------------------------------
# Paged fused dequant-attention (ISSUE 7 tentpole): gather K/V pages via
# the block table, dequantize in-kernel, attend — vs the jnp oracle.
# ---------------------------------------------------------------------------
def _paged_pools(k, v, bits, group, page_size, rng):
    """Scatter dense (B,H,S,D) K/V into shuffled quantized page pools."""
    b, hkv, s, d = k.shape
    kc8, ks = K.quantize_ref(k, bits, group)
    vc8, vs = K.quantize_ref(v, bits, group)
    kc = K.pack_int4_ref(kc8) if bits == 4 else kc8
    vc = K.pack_int4_ref(vc8) if bits == 4 else vc8
    pps = s // page_size
    n_pages = 1 + b * pps          # page 0 = scratch, never mapped
    bt = rng.permutation(np.arange(1, n_pages)).reshape(b, pps)
    cw, cdt = kc.shape[-1], np.asarray(kc).dtype   # u8 packed / i8 plain
    kcp = np.zeros((n_pages, hkv, page_size, cw), cdt)
    vcp = np.zeros((n_pages, hkv, page_size, cw), cdt)
    ksp = np.zeros((n_pages, hkv, page_size, d // group), np.float32)
    vsp = np.zeros((n_pages, hkv, page_size, d // group), np.float32)
    for i in range(b):
        for p in range(pps):
            sl = slice(p * page_size, (p + 1) * page_size)
            pg = bt[i, p]
            kcp[pg], vcp[pg] = np.asarray(kc[i, :, sl]), np.asarray(vc[i, :, sl])
            ksp[pg], vsp[pg] = np.asarray(ks[i, :, sl]), np.asarray(vs[i, :, sl])
    return ((jnp.asarray(kcp), jnp.asarray(ksp), jnp.asarray(vcp),
             jnp.asarray(vsp)), jnp.asarray(bt, jnp.int32), (kc8, ks, vc8, vs))


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("b,hkv,gq,d,s,group,ps", [
    (2, 2, 4, 64, 256, 32, 16),
    (1, 4, 8, 128, 128, 64, 8),
    (3, 1, 2, 128, 512, 128, 64),
])
def test_paged_attention_matches_ref(bits, b, hkv, gq, d, s, group, ps):
    rng = np.random.default_rng(bits * 31 + s + ps)
    q = jnp.asarray(rng.standard_normal((b, hkv, gq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    (pools, bt, dense) = _paged_pools(k, v, bits, group, ps, rng)
    kv_lens = jnp.asarray([s, max(s // 2 - 3, 1), 1][:b], jnp.int32)
    # the PUBLIC jitted wrapper, not the raw kernel: parity covers the
    # op surface the serving stack actually calls
    out = K.paged_attention_op(q, *pools, bt, kv_lens, bits=bits,
                               group=group, interpret=True)
    ref = K.paged_attention_ref(q, *pools, bt, kv_lens, bits=bits,
                                group=group)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=1e-4)
    # ... and the oracle itself must agree with DENSE ragged attention on
    # the pre-scatter arrays: the block-table gather is a pure relabeling
    kc8, ks, vc8, vs = dense
    dense_ref = K.decode_attention_ref(q, kc8, ks, vc8, vs, group,
                                       kv_len=kv_lens)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(dense_ref),
                               atol=1e-6, rtol=1e-6)


def test_paged_attention_scratch_pages_inert():
    """Unmapped block-table entries point at scratch page 0; whatever
    garbage it holds must not leak into any row's output (masking by
    kv_len kills it)."""
    from repro.kernels.paged_attention import paged_attention

    rng = np.random.default_rng(5)
    b, hkv, gq, d, s, group, ps = 2, 2, 4, 64, 128, 32, 16
    q = jnp.asarray(rng.standard_normal((b, hkv, gq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    (pools, bt, _) = _paged_pools(k, v, 8, group, ps, rng)
    kcp, ksp, vcp, vsp = (np.asarray(p).copy() for p in pools)
    # poison the scratch page and point every beyond-len entry at it
    kcp[0], vcp[0] = 127, -128
    ksp[0], vsp[0] = 1e9, 1e9
    kv_lens = jnp.asarray([ps + 3, ps], jnp.int32)   # only pages 0..1 live
    bt_np = np.asarray(bt).copy()
    bt_np[:, 2:] = 0
    out_a = paged_attention(q, *pools, jnp.asarray(bt_np), kv_lens,
                            bits=8, group=group, interpret=True)
    out_b = paged_attention(q, jnp.asarray(kcp), jnp.asarray(ksp),
                            jnp.asarray(vcp), jnp.asarray(vsp),
                            jnp.asarray(bt_np), kv_lens, bits=8,
                            group=group, interpret=True)
    np.testing.assert_array_equal(np.asarray(out_a), np.asarray(out_b))


# ---------------------------------------------------------------------------
# Paged multi-token verify attention (ISSUE 10 tentpole): W consecutive
# verify queries per slot with the staircase causal mask —
# paged_verify_attention_op vs paged_verify_attention_ref.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("b,hkv,gq,d,s,group,ps,w", [
    (2, 2, 4, 64, 256, 32, 16, 3),
    (1, 4, 8, 128, 128, 64, 8, 5),
    (3, 1, 2, 128, 512, 128, 64, 2),
])
def test_paged_verify_attention_matches_ref(bits, b, hkv, gq, d, s, group,
                                            ps, w):
    rng = np.random.default_rng(bits * 77 + s + ps + w)
    q = jnp.asarray(rng.standard_normal((b, hkv, w, gq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    (pools, bt, _) = _paged_pools(k, v, bits, group, ps, rng)
    # keep kv_lens + w - 1 <= s so every staircase row stays in range
    kv_lens = jnp.asarray([s - w, max(s // 2 - 3, 1), 1][:b], jnp.int32)
    out = K.paged_verify_attention_op(q, *pools, bt, kv_lens, bits=bits,
                                      group=group, interpret=True)
    ref = K.paged_verify_attention_ref(q, *pools, bt, kv_lens, bits=bits,
                                       group=group)
    assert out.shape == (b, hkv, w, gq, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=1e-4)


def test_paged_verify_attention_width1_matches_paged_attention():
    """W=1 degenerates to the single-token paged decode kernel: the
    staircase mask collapses to the plain kv_len mask."""
    rng = np.random.default_rng(11)
    b, hkv, gq, d, s, group, ps = 2, 2, 4, 64, 128, 32, 16
    q = jnp.asarray(rng.standard_normal((b, hkv, 1, gq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
    (pools, bt, _) = _paged_pools(k, v, 8, group, ps, rng)
    kv_lens = jnp.asarray([s, s // 2], jnp.int32)
    ver = K.paged_verify_attention_op(q, *pools, bt, kv_lens, bits=8,
                                      group=group, interpret=True)
    dec = K.paged_attention_op(q[:, :, 0], *pools, bt, kv_lens, bits=8,
                               group=group, interpret=True)
    np.testing.assert_allclose(np.asarray(ver[:, :, 0]), np.asarray(dec),
                               atol=1e-6, rtol=1e-6)


def test_paged_verify_attention_rejected_suffix_blind():
    """Query j must be blind to positions > kv_lens + j - 1: clobbering
    the KV rows of LATER verify positions cannot change row j's output —
    the property that makes host-side accept-prefix decisions sound."""
    rng = np.random.default_rng(23)
    b, hkv, gq, d, s, group, ps, w = 1, 2, 4, 64, 128, 32, 16, 4
    q = jnp.asarray(rng.standard_normal((b, hkv, w, gq, d)), jnp.float32)
    k = rng.standard_normal((b, hkv, s, d)).astype(np.float32)
    v = rng.standard_normal((b, hkv, s, d)).astype(np.float32)
    base = 40  # query 0's visible length; verify rows sit at 39..42
    (pools, bt, _) = _paged_pools(jnp.asarray(k), jnp.asarray(v), 8, group,
                                  ps, np.random.default_rng(99))
    out_a = K.paged_verify_attention_op(q, *pools, bt,
                                        jnp.asarray([base], jnp.int32),
                                        bits=8, group=group, interpret=True)
    # clobber the last verify position's KV (position base + w - 2 = 42);
    # same pool-scatter seed, so the block tables are identical
    k2, v2 = k.copy(), v.copy()
    k2[:, :, base + w - 2] = 9.0
    v2[:, :, base + w - 2] = -9.0
    (pools2, _, _) = _paged_pools(jnp.asarray(k2), jnp.asarray(v2), 8, group,
                                  ps, np.random.default_rng(99))
    out_b = K.paged_verify_attention_op(q, *pools2, bt,
                                        jnp.asarray([base], jnp.int32),
                                        bits=8, group=group, interpret=True)
    # rows 0..w-2 never see position base+w-2; only the last row may move
    np.testing.assert_array_equal(np.asarray(out_a[:, :, :w - 1]),
                                  np.asarray(out_b[:, :, :w - 1]))
    assert not np.array_equal(np.asarray(out_a[:, :, w - 1]),
                              np.asarray(out_b[:, :, w - 1]))
