"""Multi-tenant continuous-batching serving with a compressed prefix-KV
pool (DESIGN.md §9).

Two tenants share one runtime: an *interactive* tenant (chat-style, tight
TTFT expectations) and a *batch* tenant (offline summarization).  The
scheduler orders admissions by SLO class, the Service-Aware Controller
picks a compression profile per pool write, and repeated prompts are
served straight from the compressed prefix pool — real bytes, real
decompression, real decode on the tiny reference model.

    PYTHONPATH=src python examples/multi_tenant_serving.py

Note: trains/loads the tiny reference LM on first use (cached under
~/.cache/repro; set REPRO_REF_STEPS to shrink it).
"""
from repro.controller import ServiceAwareController
from repro.core.kvcache import KVCache
from repro.core.profiles import measure_profile
from repro.core.strategy import BASELINES, IDENTITY_STRATEGY, StrategyConfig
from repro.data.synthetic import WORKLOADS
from repro.serving import GBPS, BandwidthTrace, SchedulerConfig
from repro.serving.engine import RuntimeConfig, ServingRuntime


def build_controller() -> ServiceAwareController:
    """Profiles measured on sample KV (no quality runs: keep startup fast;
    q defaults to 1.0 so every profile is eligible)."""
    samples = [KVCache.random(num_layers=4, kv_heads=2, seq=96, head_dim=32,
                              seed=s) for s in range(2)]
    strategies = [
        IDENTITY_STRATEGY,
        BASELINES["kivi"],
        StrategyConfig(quantizer="uniform", key_bits=8, value_bits=8,
                       granularity="per_channel", codec="zstd3"),
        StrategyConfig(quantizer="uniform", key_bits=4, value_bits=4,
                       granularity="per_channel", codec="zstd3"),
    ]
    profiles = [measure_profile(s, samples) for s in strategies]
    return ServiceAwareController({w: profiles for w in WORKLOADS})


def main():
    rt = ServingRuntime(
        controller=build_controller(),
        config=RuntimeConfig(seq=96, decode_tokens=10,
                             prefill_tok_s=2000.0, decode_tok_s=400.0),
        # Constrained cross-node link (the paper's regime): slow enough
        # that the controller picks real compression for pool writes.
        trace=BandwidthTrace.constant(0.01 * GBPS),
        scheduler=SchedulerConfig(max_slots=6, max_prefills_per_step=2,
                                  max_queue=32))

    # Interactive tenant: few distinct prompts, heavily repeated (chat
    # prefixes).  Batch tenant: all-distinct long-tail prompts.
    arrivals = []
    for i in range(8):
        arrivals.append(("qalike", "interactive", i % 2))
    for i in range(6):
        arrivals.append(("summlike", "batch", 100 + i))

    for workload, tenant, seed in arrivals:
        rt.submit(workload, slo_class=tenant, prompt_seed=seed)
        rt.step()
    rt.run()

    print(f"{'rid':>3} {'tenant':<12} {'workload':<9} {'src':<5} "
          f"{'profile':<28} {'ttft(ms)':>9} {'jct(ms)':>9} {'wire(KB)':>9}")
    for r in sorted(rt.completed, key=lambda r: r.rid):
        print(f"{r.rid:>3} {r.slo_class:<12} {r.workload:<9} "
              f"{'pool' if r.pool_hit else 'cold':<5} {r.profile:<28} "
              f"{r.ttft*1e3:>9.1f} {r.jct*1e3:>9.1f} "
              f"{r.wire_bytes/1e3:>9.1f}")

    s = rt.summary()
    print(f"\ncompleted={s['completed']} rejected={s['rejected']} "
          f"max_in_flight={s['max_in_flight']} "
          f"pool_hit_rate={s['pool_hit_rate']:.2f}")
    print(f"mean TTFT: pool hits {s.get('mean_ttft_hit', 0)*1e3:.1f} ms vs "
          f"cold prefill {s.get('mean_ttft_cold', 0)*1e3:.1f} ms")
    print(f"store: {int(s['store_entries'])} prefixes, "
          f"{s['store_used_bytes']/1e3:.0f} KB of "
          f"{s['store_capacity_bytes']/1e6:.0f} MB, "
          f"hit_rate={s['store_hit_rate']:.2f}")


if __name__ == "__main__":
    main()
