import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first initialisation.  This module is the only place the 512
# placeholder devices exist — tests/benches see the real single CPU device.

import argparse
import json
import sys
import time
import traceback
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.configs import (
    ASSIGNED_ARCHS,
    SHAPES_BY_NAME,
    get_config,
    list_archs,
    supported_shapes,
)
from repro.distribution.optimizer import OptConfig, init_opt_state
from repro.distribution.sharding import (
    cache_pspecs,
    inputs_pspecs,
    to_named,
    tree_pspecs,
)
from repro.distribution.steps import make_decode_step, make_prefill_step, make_train_step
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import RooflineReport, analyze, model_flops_for
from repro.models import init_params, make_inputs_for_shape
from jax.sharding import NamedSharding, PartitionSpec as P


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool,
                verbose: bool = False, include_transfer: bool = False,
                transfer_bits: int = 4) -> Dict:
    """Lower + compile one (arch × shape × mesh) cell; return roofline data."""
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    chips = mesh.devices.size

    t0 = time.time()
    # serving cells read bf16 weights (halved HBM traffic); training keeps
    # fp32 masters
    import jax.numpy as jnp
    p_dtype = jnp.bfloat16 if shape.kind in ("prefill", "decode") else None
    params_abs, axes_tree = init_params(cfg, abstract=True, dtype=p_dtype)
    param_specs = tree_pspecs(axes_tree, params_abs, mesh)
    param_sh = to_named(param_specs, mesh)

    inputs = make_inputs_for_shape(cfg, shape, abstract=True)
    in_specs = inputs_pspecs(inputs, mesh, cfg)
    in_sh = to_named(in_specs, mesh)

    with mesh:
        if shape.kind == "train":
            oc = OptConfig()
            opt_abs = init_opt_state(params_abs)
            opt_specs = {"mu": param_specs, "nu": param_specs, "step": P()}
            opt_sh = to_named(opt_specs, mesh)
            step = make_train_step(cfg, oc, remat=True)
            lowered = jax.jit(
                step,
                in_shardings=(param_sh, opt_sh, in_sh["batch"]),
                out_shardings=(param_sh, opt_sh, None),
            ).lower(params_abs, opt_abs, inputs["batch"])
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, max_len=inputs["max_len"])
            lowered = jax.jit(
                step, in_shardings=(param_sh, in_sh["batch"]),
            ).lower(params_abs, inputs["batch"])
        else:  # decode
            step = make_decode_step(cfg)
            cache_sh = to_named(in_specs["caches"], mesh)
            lowered = jax.jit(
                step,
                in_shardings=(param_sh, cache_sh, in_sh["tokens"], in_sh["pos"]),
                out_shardings=(None, cache_sh),
            ).lower(params_abs, inputs["caches"], inputs["tokens"], inputs["pos"])

        compiled = lowered.compile()
        # Post-SPMD HLO: collectives only exist after partitioning.
        hlo_text = compiled.as_text()

    report = analyze(
        compiled, hlo_text, arch=arch, shape=shape_name, mesh_name=mesh_name,
        chips=chips,
        model_flops=model_flops_for(cfg, shape.kind, shape.seq_len,
                                    shape.global_batch),
    )
    elapsed = time.time() - t0

    result = {"report": report, "compile_seconds": elapsed}

    if verbose:
        try:
            print(compiled.memory_analysis())
        except Exception as e:  # pragma: no cover
            print(f"memory_analysis unavailable: {e}")
        from repro.launch.hlo_cost import xla_cost_analysis
        print({k: v for k, v in xla_cost_analysis(compiled).items()
               if k in ("flops", "bytes accessed")})

    # Optional: lower the compressed cross-pod KV migration for this cell
    # (the paper's data path as a compiled collective).  For enc-dec the
    # payload includes the cross-attention KV (the dominant whisper term).
    if include_transfer and multi_pod and shape.kind == "decode":
        from repro.distribution.kv_transfer import make_kv_transfer
        from repro.models.transformer import init_cache
        if cfg.encoder_decoder:
            caches = init_cache(cfg, shape.global_batch,
                                max_len=min(cfg.dec_seq, shape.seq_len),
                                enc_len=shape.seq_len, abstract=True)
        else:
            caches = init_cache(cfg, shape.global_batch, shape.seq_len,
                                abstract=True)
        with mesh:
            fn, _ = make_kv_transfer(mesh, caches, bits=transfer_bits)
            xfer_lowered = fn.lower(caches)
            xfer_compiled = xfer_lowered.compile()
            xfer_text = xfer_compiled.as_text()
        xfer_report = analyze(
            xfer_compiled, xfer_text, arch=arch,
            shape=f"{shape_name}+kvxfer{transfer_bits}", mesh_name=mesh_name,
            chips=chips, model_flops=0.0)
        result["transfer_report"] = xfer_report
    return result


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all",
                    help="arch id, comma list, or 'all' (assigned pool)")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default="", help="write JSONL reports here")
    ap.add_argument("--verbose", action="store_true")
    ap.add_argument("--include-transfer", action="store_true")
    args = ap.parse_args(argv)

    archs = ASSIGNED_ARCHS if args.arch == "all" else args.arch.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    rows = []
    failures = 0
    for arch in archs:
        cfg = get_config(arch)
        shapes = supported_shapes(cfg) if args.shape == "all" \
            else args.shape.split(",")
        for shape_name in shapes:
            if shape_name not in supported_shapes(cfg):
                print(f"[skip] {arch} × {shape_name}: unsupported "
                      f"(full-attention arch, see DESIGN.md §5)")
                rows.append({"arch": arch, "shape": shape_name,
                             "status": "skipped"})
                continue
            for mp in meshes:
                tag = f"{arch} × {shape_name} × {'2x16x16' if mp else '16x16'}"
                try:
                    res = dryrun_cell(arch, shape_name, multi_pod=mp,
                                      verbose=args.verbose,
                                      include_transfer=args.include_transfer)
                    r: RooflineReport = res["report"]
                    print(f"[ok]  {tag}: dominant={r.dominant} "
                          f"tc={r.t_compute:.3e}s tm={r.t_memory:.3e}s "
                          f"tx={r.t_collective:.3e}s useful={r.useful_ratio:.2f} "
                          f"compile={res['compile_seconds']:.1f}s")
                    row = {"status": "ok", **json.loads(r.to_json()),
                           "compile_seconds": res["compile_seconds"]}
                    if "transfer_report" in res:
                        row["transfer"] = json.loads(
                            res["transfer_report"].to_json())
                    rows.append(row)
                except Exception as e:
                    failures += 1
                    print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
                    if args.verbose:
                        traceback.print_exc()
                    rows.append({"arch": arch, "shape": shape_name,
                                 "mesh": "multi" if mp else "single",
                                 "status": "fail", "error": str(e)[:500]})

    if args.out:
        with open(args.out, "w") as f:
            for row in rows:
                f.write(json.dumps(row) + "\n")
        print(f"wrote {len(rows)} rows to {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
