"""Network model: time-varying effective bandwidth + the controller's
goodput estimator.

The realized communication cost is governed by effective goodput under
contention, not nominal link speed (Sec. 3.1) — traces are piecewise
constant with optional per-transfer jitter; the estimator only sees
observed transfers (EWMA), which creates the offline→online drift the
bandit corrects.
"""
from __future__ import annotations

from bisect import bisect_right
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

GBPS = 1e9 / 8  # 1 Gbps in bytes/s


@dataclass
class BandwidthTrace:
    """Piecewise-constant B(t) in bytes/s."""

    times: List[float]   # segment start times, times[0] == 0
    values: List[float]  # bytes/s per segment
    jitter: float = 0.0  # multiplicative lognormal sigma per transfer
    seed: int = 0

    def __post_init__(self):
        assert self.times[0] == 0.0 and len(self.times) == len(self.values)

    @staticmethod
    def constant(bandwidth: float) -> "BandwidthTrace":
        return BandwidthTrace([0.0], [bandwidth])

    @staticmethod
    def steps(segments: Sequence[Tuple[float, float]],
              jitter: float = 0.0, seed: int = 0) -> "BandwidthTrace":
        ts, vs = zip(*segments)
        return BandwidthTrace(list(ts), list(vs), jitter=jitter, seed=seed)

    def at(self, t: float) -> float:
        i = bisect_right(self.times, t) - 1
        return self.values[max(i, 0)]

    def _jitter_mult(self, start: float, nbytes: float) -> float:
        """Per-transfer multiplier derived deterministically from
        (seed, start, nbytes): identical transfers get identical times
        across calls and replays, and a trace shared between the runtime
        and the simulator cannot cross-contaminate either's stream."""
        if self.jitter <= 0:
            return 1.0
        key = (self.seed,
               int(np.float64(start).view(np.uint64)),
               int(np.float64(nbytes).view(np.uint64)))
        rng = np.random.default_rng(key)
        return float(np.exp(rng.normal(0.0, self.jitter)))

    def transfer_time(self, start: float, nbytes: float) -> float:
        """Time to push nbytes starting at `start`, integrating over the
        trace (with optional per-transfer jitter)."""
        if nbytes <= 0:
            return 0.0
        mult = self._jitter_mult(start, nbytes)
        remaining = nbytes
        t = start
        i = bisect_right(self.times, t) - 1
        while True:
            rate = self.values[max(i, 0)] * mult
            seg_end = self.times[i + 1] if i + 1 < len(self.times) else float("inf")
            dt_seg = seg_end - t
            can = rate * dt_seg
            if can >= remaining or seg_end == float("inf"):
                return (t + remaining / rate) - start
            remaining -= can
            t = seg_end
            i += 1


@dataclass
class GoodputEstimator:
    """EWMA over observed transfer goodputs — the controller's view of B."""

    alpha: float = 0.3
    initial: float = 10 * GBPS
    _est: Optional[float] = None

    def observe(self, nbytes: float, seconds: float) -> None:
        if seconds <= 0 or nbytes <= 0:
            return
        goodput = nbytes / seconds
        self._est = goodput if self._est is None else \
            (1 - self.alpha) * self._est + self.alpha * goodput

    @property
    def estimate(self) -> float:
        return self._est if self._est is not None else self.initial
