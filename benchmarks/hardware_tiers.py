"""Paper Fig. 12 top row: JCT across hardware tiers (consumer 10 Gbps /
workstation 50 Gbps / datacenter 100 Gbps prefill nodes with different
compute speeds), scaled to the simulator's calibrated throughputs."""
from __future__ import annotations

import time

from benchmarks.common import cached_profiles, emit
from repro.controller import ServiceAwareController
from repro.data.synthetic import WORKLOADS
from repro.serving import (
    GBPS,
    BandwidthTrace,
    KVServePolicy,
    NoCompressionPolicy,
    SimConfig,
    Simulator,
    StaticPolicy,
    WorkloadMix,
)

# tier: (bandwidth gbps [scaled 1/100], prefill tokens/s)
TIERS = {
    "consumer_10g": (0.10, 12000.0),
    "workstation_50g": (0.50, 25000.0),
    "datacenter_100g": (1.00, 60000.0),
}


def run(smoke: bool = False) -> None:
    profiles = cached_profiles()
    kivi = next(p for p in profiles if "kivi" in p.strategy.short_name())
    n = 12 if smoke else 30
    reqs = lambda: WorkloadMix(rate=2.0, seed=4, q_min=0.0).generate(n)
    tiers = dict(list(TIERS.items())[::2]) if smoke else TIERS

    for tier, (bw, ptok) in tiers.items():
        t0 = time.perf_counter()
        cfg = SimConfig(prefill_tok_s=ptok)
        trace = lambda: BandwidthTrace.constant(bw * GBPS)
        d = Simulator(cfg, NoCompressionPolicy(), trace(), reqs()).run()
        k = Simulator(cfg, StaticPolicy(kivi, "kivi"), trace(), reqs()).run()
        c = ServiceAwareController({w: profiles for w in WORKLOADS})
        kv = Simulator(cfg, KVServePolicy(c), trace(), reqs()).run()
        us = (time.perf_counter() - t0) * 1e6
        emit(f"fig12_{tier}", us,
             f"default={d.mean_jct():.2f}s kivi={k.mean_jct():.2f}s "
             f"kvserve={kv.mean_jct():.2f}s "
             f"speedup={d.mean_jct()/kv.mean_jct():.2f}x")


if __name__ == "__main__":
    run()
