"""Tiered KV hierarchy: TTFT vs hot-tier capacity (ISSUE 4 tentpole).

The paper's KV-disaggregated TTFT story (Sec. 7.2) assumes prefixes live
in a *memory hierarchy*: a repeat prompt served from device-adjacent HBM
costs microseconds, from host DRAM milliseconds, and only a remote-pool
refetch pays wire time — while a cold miss recomputes prefill.  This sweep
drives the continuous ``ServingRuntime`` (pool mode, virtual clock) over a
shrinking hot tier at a 50 Mbps remote link and reports the mean hit TTFT
per configuration, plus the demotion behaviour when the hot tier only
holds a fraction of the working set.

Deterministic acceptance (asserted every run):
  * ample hot tier  -> hits served from HBM; TTFT beats the remote path
  * hot tier 0 B    -> graceful degradation: requests still complete as
    *pool hits* over the remote link (no crash), and that remote-path
    TTFT still beats cold recomputation
  * fractional hot tier -> demotions occur (entries pushed down, not
    dropped)

CLI: ``--smoke`` shrinks to CI-sized settings; ``--json PATH`` archives
the emitted rows.
"""
from __future__ import annotations

import argparse
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

from benchmarks.common import emit, write_json
from repro.serving import BandwidthTrace, GBPS, SchedulerConfig, TierSpec

REMOTE_GBPS = 0.05          # 50 Mbps pool link
WORKLOAD_CYCLE = ("qalike", "codelike", "mathlike", "summlike")


def _tiers(hot_bytes: int, dram_bytes: int,
           remote_trace: BandwidthTrace) -> List[TierSpec]:
    return [
        TierSpec("hbm", hot_bytes, bandwidth=64e9),
        TierSpec("dram", dram_bytes, bandwidth=8e9, fetch_overhead=5e-4),
        TierSpec("remote", 64 << 20, bandwidth=remote_trace,
                 fetch_overhead=0.002, observe_goodput=True),
    ]


def _run_wave(tiers: List[TierSpec], n: int, seq: int, decode_tokens: int
              ) -> Tuple[float, float, float, object]:
    """Cold wave (distinct prompts) then a hit wave (same prompts).
    Returns (mean_hit_ttft, mean_cold_ttft, hit_rate, runtime)."""
    from repro.core.profiles import Profile
    from repro.core.strategy import StrategyConfig
    from repro.serving.engine import RuntimeConfig, ServingRuntime

    profile = Profile(StrategyConfig(quantizer="uniform", key_bits=8,
                                     value_bits=8, granularity="per_channel"),
                      cr=2.0, s_enc=5e8, s_dec=5e8)
    rt = ServingRuntime(
        static_profile=profile,
        # Loaded-cluster pool regime: prefill is the expensive path, and
        # decode_tok_s=20 keeps the virtual clock moving past every
        # off-path pool write before the hit wave looks it up.
        config=RuntimeConfig(seq=seq, decode_tokens=decode_tokens,
                             prefill_tok_s=150.0, decode_tok_s=20.0,
                             tiers=tiers),
        trace=BandwidthTrace.constant(REMOTE_GBPS * GBPS),
        scheduler=SchedulerConfig(max_slots=6, max_prefills_per_step=2,
                                  max_queue=4 * n))
    for i in range(n):                      # cold wave
        rt.submit(WORKLOAD_CYCLE[i % 4], prompt_seed=100 + 7 * i)
        rt.run()
    for i in range(n):                      # hit wave, same prompts
        rt.submit(WORKLOAD_CYCLE[i % 4], prompt_seed=100 + 7 * i)
        rt.run()
    done = rt.completed
    assert len(done) == 2 * n               # graceful: nothing crashed/shed
    cold = [r for r in done if not r.pool_hit]
    hits = [r for r in done if r.pool_hit]
    assert len(cold) == n and len(hits) == n, \
        "every repeat prompt must be served as a pool hit"
    return (float(np.mean([r.ttft for r in hits])),
            float(np.mean([r.ttft for r in cold])),
            len(hits) / len(done), rt)


def run(smoke: bool = False) -> None:
    n = 3 if smoke else 6
    seq = 48 if smoke else 96
    decode_tokens = 4 if smoke else 8
    remote_trace = BandwidthTrace.constant(REMOTE_GBPS * GBPS)

    # Probe one entry's wire footprint to size the fractional hot tier.
    t0 = time.perf_counter()
    _, _, _, probe = _run_wave(_tiers(4 << 20, 16 << 20, remote_trace),
                               1, seq, decode_tokens)
    entry_bytes = probe.completed[0].wire_bytes
    emit(f"tiered_probe_seq{seq}", (time.perf_counter() - t0) * 1e6,
         f"entry_wire_bytes={entry_bytes}")

    configs = {
        # name: (hot_bytes, dram_bytes)
        "hot_ample": (4 << 20, 16 << 20),
        "hot_fraction": (int(entry_bytes * 1.5), 16 << 20),
        "dram_only": (0, 16 << 20),
        "remote_only": (0, 0),
    }
    results = {}
    for name, (hot, dram) in configs.items():
        t0 = time.perf_counter()
        hit_ttft, cold_ttft, hit_rate, rt = _run_wave(
            _tiers(hot, dram, remote_trace), n, seq, decode_tokens)
        s = rt.store.stats
        results[name] = hit_ttft
        emit(f"tiered_ttft_{name}", (time.perf_counter() - t0) * 1e6,
             f"hit_ttft={hit_ttft*1e3:.3f}ms cold_ttft={cold_ttft*1e3:.1f}ms "
             f"speedup={cold_ttft/hit_ttft:.1f}x "
             f"hbm_hits={s.tier_hits.get('hbm', 0)} "
             f"dram_hits={s.tier_hits.get('dram', 0)} "
             f"remote_hits={s.tier_hits.get('remote', 0)} "
             f"promotions={s.promotions} demotions={s.demotions} "
             f"evictions={s.evictions}")
        # Tail metrics (ISSUE 5 satellite): TTFT/JCT distribution, not
        # just the per-wave means.
        rs = rt.summary()
        emit(f"tiered_tails_{name}", 0.0,
             " ".join(f"{k}={rs[k]*1e3:.3f}ms"
                      for k in ("ttft_p50", "ttft_p95", "ttft_p99",
                                "jct_p50", "jct_p95", "jct_p99")
                      if k in rs))

        # ---- deterministic acceptance (virtual clock) ----
        if name == "hot_ample":
            assert s.tier_hits.get("hbm", 0) == n, s.tier_hits
        if name == "remote_only":
            assert s.tier_hits.get("remote", 0) == n, s.tier_hits
            assert hit_ttft < cold_ttft, (hit_ttft, cold_ttft)
        if name == "hot_fraction":
            # the working set exceeds the hot tier: victims demote down
            # the hierarchy instead of being dropped
            assert s.demotions > 0 and s.evictions == 0, \
                (s.demotions, s.evictions)

    # The tentpole crossover: a hot-tier hit beats a remote refetch, with
    # the DRAM tier strictly in between.
    assert results["hot_ample"] < results["dram_only"] < \
        results["remote_only"], results


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized settings; crash = fail")
    ap.add_argument("--json", default="",
                    help="archive emitted rows to this JSON path")
    args = ap.parse_args(argv)
    run(smoke=args.smoke)
    if args.json:
        write_json(args.json)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
