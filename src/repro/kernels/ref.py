"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

All kernels are validated against these in interpret mode across
shape/dtype sweeps (tests/test_kernels_*.py).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Group quantization (symmetric, per-group along the last axis)
# ---------------------------------------------------------------------------
def quantize_ref(x: jnp.ndarray, bits: int, group: int
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (..., D) -> (codes int8 (..., D), scales f32 (..., D/group))."""
    d = x.shape[-1]
    assert d % group == 0
    qmax = (1 << (bits - 1)) - 1
    xg = x.reshape(x.shape[:-1] + (d // group, group)).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xg), axis=-1)
    scale = jnp.maximum(amax / qmax, 1e-8)
    q = jnp.clip(jnp.round(xg / scale[..., None]), -qmax - 1, qmax)
    return q.reshape(x.shape).astype(jnp.int8), scale


def dequantize_ref(codes: jnp.ndarray, scale: jnp.ndarray, group: int,
                   dtype=jnp.float32) -> jnp.ndarray:
    d = codes.shape[-1]
    qg = codes.reshape(codes.shape[:-1] + (d // group, group)).astype(jnp.float32)
    x = qg * scale[..., None].astype(jnp.float32)
    return x.reshape(codes.shape).astype(dtype)


def pack_int4_ref(codes: jnp.ndarray) -> jnp.ndarray:
    """int8 codes in [-8,7] -> packed uint8 (last dim halved)."""
    u = (codes.astype(jnp.int32) + 8).astype(jnp.uint8)
    return (u[..., 0::2] | (u[..., 1::2] << 4)).astype(jnp.uint8)


def unpack_int4_ref(packed: jnp.ndarray) -> jnp.ndarray:
    lo = (packed & jnp.uint8(0x0F)).astype(jnp.int32) - 8
    hi = (packed >> jnp.uint8(4)).astype(jnp.int32) - 8
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(packed.shape[:-1] + (packed.shape[-1] * 2,)).astype(jnp.int8)


def quant_pack_ref(x: jnp.ndarray, bits: int, group: int
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle for ops.quant_pack_op: group-quantize, then pack to nibbles
    when bits == 4 (int8 codes pass through)."""
    codes, scale = quantize_ref(x, bits, group)
    if bits == 4:
        codes = pack_int4_ref(codes)
    return codes, scale


def dequant_unpack_ref(codes: jnp.ndarray, scale: jnp.ndarray, bits: int,
                       group: int, dtype=jnp.float32) -> jnp.ndarray:
    """Oracle for ops.dequant_unpack_op: unpack nibbles when bits == 4,
    then dequantize."""
    if bits == 4:
        codes = unpack_int4_ref(codes)
    return dequantize_ref(codes, scale, group, dtype=dtype)


# ---------------------------------------------------------------------------
# Hadamard transform (orthonormal; D power of two)
# ---------------------------------------------------------------------------
def hadamard_matrix(n: int, dtype=jnp.float32) -> jnp.ndarray:
    assert n & (n - 1) == 0
    h = jnp.array([[1.0]], dtype=jnp.float32)
    while h.shape[0] < n:
        h = jnp.block([[h, h], [h, -h]])
    return (h / math.sqrt(n)).astype(dtype)


def hadamard_ref(x: jnp.ndarray) -> jnp.ndarray:
    h = hadamard_matrix(x.shape[-1])
    return (x.astype(jnp.float32) @ h).astype(x.dtype)


# ---------------------------------------------------------------------------
# Quantized flash-decode attention
# ---------------------------------------------------------------------------
def decode_attention_ref(
    q: jnp.ndarray,        # (B, Hkv, Gq, D) f32/bf16 — query heads grouped per kv head
    k_codes: jnp.ndarray,  # (B, Hkv, S, D) int8
    k_scale: jnp.ndarray,  # (B, Hkv, S, D/group) f32
    v_codes: jnp.ndarray,  # (B, Hkv, S, D) int8
    v_scale: jnp.ndarray,  # (B, Hkv, S, D/group) f32
    group: int,
    kv_len: Optional[jnp.ndarray] = None,  # scalar, or (B,) per-slot lengths
) -> jnp.ndarray:
    """Attention of one new token against a quantized KV cache.  A (B,)
    ``kv_len`` masks each batch row at its own slot length (the ragged
    slot-arena decode)."""
    b, hkv, gq, d = q.shape
    s = k_codes.shape[2]
    k = dequantize_ref(k_codes, k_scale, group)  # (B,Hkv,S,D)
    v = dequantize_ref(v_codes, v_scale, group)
    scores = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32), k)
    scores = scores / math.sqrt(d)
    if kv_len is not None:
        lens = jnp.atleast_1d(jnp.asarray(kv_len))          # (1,) or (B,)
        mask = jnp.arange(s)[None, :] < lens[:, None]       # (B|1, S)
        scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", probs, v)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Paged quantized decode attention (block-table gather + fused dequant)
# ---------------------------------------------------------------------------
def paged_verify_attention_ref(
    q: jnp.ndarray,             # (B, Hkv, W, Gq, D)
    k_codes: jnp.ndarray,       # (P, Hkv, PS, D) int8 or (P, Hkv, PS, D/2) u8
    k_scale: jnp.ndarray,       # (P, Hkv, PS, D/group) f32
    v_codes: jnp.ndarray,
    v_scale: jnp.ndarray,
    block_tables: jnp.ndarray,  # (B, PPS) int32 page ids; 0 = unmapped
    kv_lens: jnp.ndarray,       # (B,) int32; query 0's visible length
    bits: int,
    group: int,
) -> jnp.ndarray:
    """Oracle for kernels/paged_verify_attention.py: the speculative
    multi-token verify step.  Query ``j`` of slot ``b`` attends cache
    positions ``< kv_lens[b] + j`` — the staircase causal mask over the
    ``W`` consecutive verify positions (each new token's own scattered
    KV row included, its successors excluded)."""
    bt = jnp.asarray(block_tables, jnp.int32)
    b, hkv, w, gq, d = q.shape

    def gather(pool):
        g = jnp.take(pool, bt, axis=0)       # (B, PPS, Hkv, PS, X)
        g = jnp.moveaxis(g, 2, 1)            # (B, Hkv, PPS, PS, X)
        return g.reshape(g.shape[0], g.shape[1], -1, g.shape[-1])

    kc, ks = gather(k_codes), gather(k_scale)
    vc, vs = gather(v_codes), gather(v_scale)
    if bits == 4:
        kc, vc = unpack_int4_ref(kc), unpack_int4_ref(vc)
    k = dequantize_ref(kc, ks, group)        # (B, Hkv, S, D)
    v = dequantize_ref(vc, vs, group)
    s = k.shape[2]
    scores = jnp.einsum("bhwgd,bhsd->bhwgs", q.astype(jnp.float32), k)
    scores = scores / math.sqrt(d)
    lens = jnp.asarray(kv_lens, jnp.int32)   # (B,)
    limit = lens[:, None] + jnp.arange(w)[None, :]          # (B, W)
    mask = jnp.arange(s)[None, None, :] < limit[..., None]  # (B, W, S)
    scores = jnp.where(mask[:, None, :, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhwgs,bhsd->bhwgd", probs, v)
    return out.astype(q.dtype)


def paged_attention_ref(
    q: jnp.ndarray,             # (B, Hkv, Gq, D)
    k_codes: jnp.ndarray,       # (P, Hkv, PS, D) int8 or (P, Hkv, PS, D/2) u8
    k_scale: jnp.ndarray,       # (P, Hkv, PS, D/group) f32
    v_codes: jnp.ndarray,
    v_scale: jnp.ndarray,
    block_tables: jnp.ndarray,  # (B, PPS) int32 page ids; 0 = unmapped
    kv_lens: jnp.ndarray,       # (B,) int32 valid lengths
    bits: int,
    group: int,
) -> jnp.ndarray:
    """Oracle for kernels/paged_attention.py: materialize each slot's
    pages into a contiguous (B, Hkv, S, ·) view, then reuse the dense
    decode-attention oracle with per-slot masking."""
    bt = jnp.asarray(block_tables, jnp.int32)

    def gather(pool):
        g = jnp.take(pool, bt, axis=0)       # (B, PPS, Hkv, PS, X)
        g = jnp.moveaxis(g, 2, 1)            # (B, Hkv, PPS, PS, X)
        return g.reshape(g.shape[0], g.shape[1], -1, g.shape[-1])

    kc, ks = gather(k_codes), gather(k_scale)
    vc, vs = gather(v_codes), gather(v_scale)
    if bits == 4:
        kc, vc = unpack_int4_ref(kc), unpack_int4_ref(vc)
    return decode_attention_ref(q, kc, ks, vc, vs, group,
                                kv_len=jnp.asarray(kv_lens, jnp.int32))
