"""Service-Aware Online Controller: end-to-end selection behaviour."""
import time

import numpy as np
import pytest

from repro.controller import ServiceAwareController, ServiceContext
from repro.core.profiles import IDENTITY_PROFILE


WORKLOADS = ("mathlike", "codelike", "qalike", "summlike")


@pytest.fixture()
def controller(synthetic_profiles):
    return ServiceAwareController({w: synthetic_profiles for w in WORKLOADS})


def _ctx(bandwidth, q_min=0.9, slo=0.0, v=1e8, w="qalike"):
    return ServiceContext(w, bandwidth, slo, q_min, t_model=0.01, kv_bytes=v)


def test_low_bandwidth_selects_compression(controller):
    d = controller.select(_ctx(bandwidth=1e7))
    assert d.profile.cr > 1.0


def test_high_bandwidth_bypasses_compression(controller):
    """Paper Sec 7.2: above the benefit threshold the controller must
    converge to the uncompressed baseline, not degrade it."""
    d = controller.select(_ctx(bandwidth=1e13))
    assert d.profile.cr == 1.0


def test_quality_budget_respected(controller, synthetic_profiles):
    d = controller.select(_ctx(bandwidth=1e7, q_min=0.99))
    assert d.profile.q("qalike") >= 0.97 or d.profile.cr == 1.0


def test_decision_latency_under_1ms(controller):
    ctx = _ctx(bandwidth=5e8)
    controller.select(ctx)  # warm
    t0 = time.perf_counter()
    n = 200
    for _ in range(n):
        controller.select(ctx)
    per_decision = (time.perf_counter() - t0) / n
    assert per_decision < 1e-3, f"{per_decision*1e3:.3f} ms/decision"


def test_feedback_changes_selection(synthetic_profiles):
    c = ServiceAwareController({w: synthetic_profiles for w in WORKLOADS})
    ctx = _ctx(bandwidth=3e8)
    d0 = c.select(ctx)
    if d0.profile.cr == 1.0:
        pytest.skip("already at identity")
    # report massive overruns for the chosen profile
    for _ in range(20):
        d = c.select(ctx)
        penalty = 10.0 if d.profile.strategy.key() == d0.profile.strategy.key() else 0.0
        c.observe(ctx, d, d.predicted + penalty)
    dn = c.select(ctx)
    assert dn.profile.strategy.key() != d0.profile.strategy.key()


def test_identity_fallback_predicted_is_comparable(controller):
    """Bugfix (PR 3): the no-envelope identity fallback built predicted as
    kv_bytes/bandwidth, omitting t_model — biasing bandit residuals for
    that arm by the whole model time.  It must equal baseline_latency."""
    from repro.controller import baseline_latency
    ctx = _ctx(bandwidth=1e8, w="unprofiled-workload")  # no envelope built
    d = controller.select(ctx)
    assert d.profile.cr == 1.0
    assert d.predicted == pytest.approx(baseline_latency(ctx))
    assert d.predicted == pytest.approx(ctx.t_model
                                        + ctx.kv_bytes / ctx.bandwidth)


def test_bucket_of_clamps_and_qmin_filters(controller):
    """Bugfix (PR 3): q_min above every bucket floor (e.g. 1.0) used to
    land in bucket 0 (floor 0.99) and silently admit profiles below the
    requested quality.  The bucket clamps to the strictest, and candidate
    eligibility re-checks q_min itself."""
    assert controller._bucket_of(1.0) == 0       # clamped to strictest
    assert controller._bucket_of(0.99) == 0
    assert controller._bucket_of(0.97) == 1      # coarsest cover kept
    assert controller._bucket_of(0.90) == 3
    assert controller._bucket_of(0.0) == len(controller.buckets) - 1
    # even at bandwidth where compression is attractive, q_min=1.0 must
    # not admit a lossy profile below it
    for bw in (1e6, 1e7, 1e8):
        d = controller.select(_ctx(bandwidth=bw, q_min=1.0))
        assert d.profile.cr == 1.0 or d.profile.q("qalike") >= 1.0, \
            (bw, d.profile.cr, d.profile.q("qalike"))


def test_workload_conditioning(synthetic_profiles):
    """Different per-workload quality -> potentially different selections."""
    profs = synthetic_profiles
    c = ServiceAwareController({w: profs for w in WORKLOADS})
    ds = {w: c.select(_ctx(bandwidth=2e8, w=w, q_min=0.95)) for w in WORKLOADS}
    # all decisions valid for their own workload's bucket
    for w, d in ds.items():
        assert d.profile.cr == 1.0 or d.profile.q(w) >= 0.90


def test_residuals_use_select_time_prediction(synthetic_profiles):
    """Bugfix (ISSUE 4): the bandit used to recompute predicted_latency
    from the *observe-time* context, so a bandwidth estimate that drifted
    between select and observe made the residual correct a prediction
    nobody acted on.  The residual must be observed - Decision.predicted
    (select-time), for every drift direction."""
    from repro.controller.latency_model import predicted_latency

    for drift in (4.0, 0.25):     # estimate rose / fell after the decision
        c = ServiceAwareController(
            {w: synthetic_profiles for w in WORKLOADS},
            use_bandit=True)
        ctx_sel = _ctx(bandwidth=2e8)
        d = c.select(ctx_sel)
        assert d.predicted == pytest.approx(
            predicted_latency(d.profile, ctx_sel))
        # EWMA bandwidth shifts before the request finishes
        ctx_obs = _ctx(bandwidth=2e8 * drift)
        observed = d.predicted + 0.125   # constant unmodelled overhead
        c.observe(ctx_obs, d, observed)
        bandit = c._bandits[("qalike", d.bucket, "")]
        res = bandit.residual_of(d.interval, d.profile)
        alpha = bandit.config.alpha
        assert res == pytest.approx(alpha * 0.125), \
            (drift, res, alpha * (observed
                                  - predicted_latency(d.profile, ctx_obs)))


def test_select_fetch_trades_tiers(controller):
    """Tier-aware fetch routing: a fast near link prefers the stored
    encoding; a slow link prefers paying a re-encode to cross with fewer
    bytes ("refetch smaller")."""
    from repro.controller import TierFetch, tier_fetch_latency

    v = 1e8
    stored = lambda bw: TierFetch(tier="dram", wire_bytes=v / 2, kv_bytes=v,
                                  bandwidth=bw, overhead=5e-4, s_dec=1e10)
    reenc = lambda bw: TierFetch(tier="dram", wire_bytes=v / 16, kv_bytes=v,
                                 bandwidth=bw, overhead=5e-4, s_enc=3e8,
                                 s_dec=3e8, variant="reencoded")
    # fast link: the re-encode time dominates -> fetch as stored
    d = controller.select_fetch(_ctx(bandwidth=1e10),
                                [stored(1e10), reenc(1e10)])
    assert d.option.variant == "stored"
    assert d.predicted == pytest.approx(tier_fetch_latency(stored(1e10)))
    # slow link: fewer bytes win despite the encode cost
    d = controller.select_fetch(_ctx(bandwidth=1e7),
                                [stored(1e7), reenc(1e7)])
    assert d.option.variant == "reencoded"
    assert d.predicted == pytest.approx(tier_fetch_latency(reenc(1e7)))
    assert controller.select_fetch(_ctx(bandwidth=1e8), []) is None


# ---------------------------------------------------------------------------
# Per-route service contexts (ISSUE 5): the bandit learns per-link drift
# ---------------------------------------------------------------------------
def test_per_route_bandits_learn_independent_residuals(synthetic_profiles):
    """Observations on one cluster link must not pollute another's
    residual corrections: a congested route accumulates its own positive
    residual while a clean route's stays at zero."""
    from dataclasses import replace

    c = ServiceAwareController({w: synthetic_profiles for w in WORKLOADS})
    base = _ctx(bandwidth=1e7)
    slow = replace(base, route="p0->d1")
    fast = replace(base, route="p0->d0")

    d = c.select(slow)
    c.observe(slow, d, d.predicted + 1.0)    # unmodelled congestion
    slow_bandit = c._bandits[("qalike", d.bucket, "p0->d1")]
    res_slow = slow_bandit.residual_of(d.interval, d.profile)
    assert res_slow > 0.0

    # the clean route's bandit is a DIFFERENT instance with zero residual
    d2 = c.select(fast)
    fast_bandit = c._bandits[("qalike", d2.bucket, "p0->d0")]
    assert fast_bandit is not slow_bandit
    assert fast_bandit.residual_of(d2.interval, d2.profile) == 0.0
    # ... and the routeless key ("" — single-link deployments) is intact
    assert ("qalike", d.bucket, "") in c._bandits


def test_predict_is_side_effect_free(controller):
    """The routing layer probes every candidate route with predict();
    that must advance neither the bandit step counter nor its RNG, so
    routing cannot perturb the selection stream."""
    ctx = _ctx(bandwidth=1e7)
    bucket = controller._bucket_of(ctx.q_min)
    bandit = controller._bandits[("qalike", bucket, "")]
    state_before = bandit._rng.getstate()
    step_before = bandit._step
    p1 = controller.predict(ctx)
    p2 = controller.predict(_ctx(bandwidth=1e10))
    assert p1 > 0 and p2 > 0
    assert bandit._rng.getstate() == state_before
    assert bandit._step == step_before
    # prediction tracks the latency model: scarce bandwidth costs more
    assert p1 > p2
