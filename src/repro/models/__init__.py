from repro.models.transformer import (
    decode_step,
    forward,
    init_cache,
    init_params,
    prefill,
)
from repro.models.io import make_inputs, make_inputs_for_shape

__all__ = [
    "decode_step", "forward", "init_cache", "init_params", "prefill",
    "make_inputs", "make_inputs_for_shape",
]
